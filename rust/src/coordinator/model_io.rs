//! Trained-model serialization: save/load `ŵ` (plus provenance) as JSON,
//! training [`Checkpoint`] persistence for `TrainSession` restore, and a
//! batch prediction service over LIBSVM files — the deployment surface a
//! downstream user of this library actually touches (`passcode train
//! --save-model m.json` → `passcode predict`).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::data::{sparse, Dataset, FeatureRemap};
use crate::solver::Checkpoint;
use crate::util::Json;

use super::config::RunConfig;

/// A trained linear model with provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    /// The maintained primal vector ŵ (Theorem 3's correct predictor).
    pub w: Vec<f64>,
    /// Loss name ("hinge", …).
    pub loss: String,
    /// Penalty parameter.
    pub c: f64,
    /// Solver that produced it (for logs only).
    pub solver: String,
    /// Training-set name.
    pub dataset: String,
}

impl Model {
    /// Build from a finished run.
    pub fn from_run(cfg: &RunConfig, c: f64, w: Vec<f64>) -> Model {
        Model {
            w,
            loss: cfg.loss.name().to_string(),
            c,
            solver: cfg.solver.name().to_string(),
            dataset: cfg.dataset.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str("passcode-model-v1")),
            ("loss", Json::str(&self.loss)),
            ("c", Json::num(self.c)),
            ("solver", Json::str(&self.solver)),
            ("dataset", Json::str(&self.dataset)),
            ("d", Json::num(self.w.len() as f64)),
            ("w", Json::arr_f64(&self.w)),
        ])
    }

    pub fn from_json(json: &Json) -> Result<Model> {
        ensure!(
            json.get("format")?.as_str()? == "passcode-model-v1",
            "not a passcode model file"
        );
        let w: Vec<f64> = json
            .get("w")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Result<_>>()?;
        ensure!(
            w.len() == json.get("d")?.as_usize()?,
            "model dimension mismatch"
        );
        Ok(Model {
            w,
            loss: json.get("loss")?.as_str()?.to_string(),
            c: json.get("c")?.as_f64()?,
            solver: json.get("solver")?.as_str()?.to_string(),
            dataset: json.get("dataset")?.as_str()?.to_string(),
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())
            .with_context(|| format!("write {}", path.as_ref().display()))
    }

    /// Load a model from disk; errors carry the offending path and what
    /// went wrong (unreadable file, malformed JSON, wrong schema) —
    /// corrupted model files must never panic the serving path.
    pub fn load(path: impl AsRef<Path>) -> Result<Model> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let json = Json::parse(&text).with_context(|| {
            format!("parse model JSON from {}", path.display())
        })?;
        Model::from_json(&json)
            .with_context(|| format!("invalid model file {}", path.display()))
    }

    /// Margin of a sparse row given as (indices, values) — raw,
    /// *unfolded* features.  Runs through the unrolled bounds-tolerant
    /// dot (`data::sparse::dot_sparse_checked`): features the model
    /// never saw contribute zero, and the scorer shards
    /// ([`crate::serve::ShardPool`]) get the same fused gather the
    /// training loop uses.
    #[inline]
    pub fn margin(&self, idx: &[u32], vals: &[f64]) -> f64 {
        sparse::dot_sparse_checked(idx, vals, &self.w)
    }

    /// Batch prediction over a (folded) dataset: returns (accuracy,
    /// predictions as ±1).
    pub fn predict_dataset(&self, ds: &Dataset) -> (f64, Vec<f64>) {
        let mut preds = Vec::with_capacity(ds.n());
        let mut correct = 0usize;
        for i in 0..ds.n() {
            let (idx, vals) = ds.x.row(i);
            // rows are folded (x = y·ẋ): recover the raw margin sign
            let folded_margin: f64 = idx
                .iter()
                .zip(vals)
                .map(|(j, v)| {
                    let j = *j as usize;
                    if j < self.w.len() {
                        self.w[j] * v
                    } else {
                        0.0
                    }
                })
                .sum();
            // folded margin > 0 ⇔ prediction matches the label
            let label = ds.y[i];
            let pred = if folded_margin > 0.0 { label } else { -label };
            if pred == label {
                correct += 1;
            }
            preds.push(pred);
        }
        (correct as f64 / ds.n().max(1) as f64, preds)
    }
}

/// Persist a training [`Checkpoint`] (the `TrainSession` snapshot) as
/// pretty JSON — the on-disk leg of checkpoint/restore.
pub fn save_checkpoint(
    ckpt: &Checkpoint,
    path: impl AsRef<Path>,
) -> Result<()> {
    std::fs::write(path.as_ref(), ckpt.to_json().to_pretty()).with_context(
        || format!("write checkpoint {}", path.as_ref().display()),
    )
}

/// Load a training [`Checkpoint`] from disk; errors carry the offending
/// path and what went wrong (unreadable file, malformed JSON, wrong
/// schema) — corrupted checkpoints must never panic a restore path.
pub fn load_checkpoint(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let json = Json::parse(&text).with_context(|| {
        format!("parse checkpoint JSON from {}", path.display())
    })?;
    Checkpoint::from_json(&json)
        .with_context(|| format!("invalid checkpoint file {}", path.display()))
}

/// Persist a [`FeatureRemap`] next to a checkpoint or model: a training
/// [`Checkpoint`] taken on a remapped dataset only resumes against the
/// *same* remapped dataset, so the map is part of the training state and
/// must survive the same round trips.
pub fn save_remap(remap: &FeatureRemap, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path.as_ref(), remap.to_json().to_pretty()).with_context(
        || format!("write remap {}", path.as_ref().display()),
    )
}

/// Load a [`FeatureRemap`]; errors carry the offending path and what
/// went wrong (unreadable file, malformed JSON, non-permutation map).
pub fn load_remap(path: impl AsRef<Path>) -> Result<FeatureRemap> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let json = Json::parse(&text)
        .with_context(|| format!("parse remap JSON from {}", path.display()))?;
    FeatureRemap::from_json(&json)
        .with_context(|| format!("invalid remap file {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::RunConfig;
    use crate::coordinator::driver;
    use crate::data::registry;

    fn trained() -> (Model, RunConfig) {
        let cfg = RunConfig {
            dataset: "rcv1".into(),
            scale: 0.02,
            epochs: 10,
            threads: 2,
            eval_every: 0,
            ..Default::default()
        };
        let out = driver::run(&cfg).unwrap();
        (Model::from_run(&cfg, 1.0, out.result.w_hat), cfg)
    }

    #[test]
    fn json_roundtrip_preserves_model() {
        let (m, _) = trained();
        let j = m.to_json().to_pretty();
        let back = Model::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn save_load_file() {
        let (m, _) = trained();
        let dir = std::env::temp_dir().join("passcode_model_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        let back = Model::load(&path).unwrap();
        assert_eq!(m.w.len(), back.w.len());
        assert_eq!(m.solver, back.solver);
    }

    #[test]
    fn rejects_foreign_json() {
        assert!(Model::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"format":"passcode-model-v1","loss":"hinge","c":1,
                      "solver":"dcd","dataset":"x","d":3,"w":[1,2]}"#;
        assert!(Model::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let (m, _) = trained();
        let dir = std::env::temp_dir().join("passcode_model_io_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        assert_eq!(Model::load(&path).unwrap(), m);
    }

    #[test]
    fn truncated_file_errors_descriptively_instead_of_panicking() {
        let (m, _) = trained();
        let dir = std::env::temp_dir().join("passcode_model_io_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("truncated.json");
        let full = m.to_json().to_pretty();
        // Chop the serialized model mid-document.
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let err = Model::load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("truncated.json"),
            "error should name the file: {msg}"
        );
        assert!(
            msg.contains("parse model JSON"),
            "error should say what failed: {msg}"
        );
    }

    #[test]
    fn corrupted_fields_error_with_path_context() {
        let dir = std::env::temp_dir().join("passcode_model_io_corrupt");
        std::fs::create_dir_all(&dir).unwrap();

        // Valid JSON, wrong schema (missing every model key).
        let path = dir.join("foreign.json");
        std::fs::write(&path, "{\"hello\": 1}").unwrap();
        let msg = format!("{:#}", Model::load(&path).unwrap_err());
        assert!(msg.contains("foreign.json"), "{msg}");
        assert!(msg.contains("invalid model file"), "{msg}");

        // Valid JSON + format tag, but w/d disagree.
        let path = dir.join("dim_mismatch.json");
        std::fs::write(
            &path,
            r#"{"format":"passcode-model-v1","loss":"hinge","c":1,
                "solver":"dcd","dataset":"x","d":3,"w":[1,2]}"#,
        )
        .unwrap();
        let msg = format!("{:#}", Model::load(&path).unwrap_err());
        assert!(msg.contains("dimension mismatch"), "{msg}");

        // Missing file: error, not panic.
        let missing = dir.join("does_not_exist.json");
        assert!(Model::load(&missing).is_err());
    }

    #[test]
    fn checkpoint_save_load_roundtrip_is_exact() {
        let ckpt = Checkpoint {
            solver: "passcode-wild".into(),
            loss: "hinge".into(),
            c: 0.5,
            // Needs all 64 bits: JSON numbers (f64) would corrupt it.
            seed: (1u64 << 60) + 3,
            epochs_done: 3,
            updates: 123,
            alpha: vec![0.0, 0.25, 0.5],
            w_hat: vec![1.5, -2.0],
            shrink: None,
        };
        let dir = std::env::temp_dir().join("passcode_ckpt_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        save_checkpoint(&ckpt, &path).unwrap();
        assert_eq!(load_checkpoint(&path).unwrap(), ckpt);
    }

    #[test]
    fn corrupted_checkpoint_errors_with_path_context() {
        let dir = std::env::temp_dir().join("passcode_ckpt_io");
        std::fs::create_dir_all(&dir).unwrap();

        // Truncated JSON.
        let path = dir.join("truncated_ckpt.json");
        std::fs::write(&path, "{\"format\": \"passcode-ch").unwrap();
        let msg = format!("{:#}", load_checkpoint(&path).unwrap_err());
        assert!(msg.contains("truncated_ckpt.json"), "{msg}");
        assert!(msg.contains("parse checkpoint JSON"), "{msg}");

        // Valid JSON, wrong schema.
        let path = dir.join("foreign_ckpt.json");
        std::fs::write(&path, "{\"hello\": 1}").unwrap();
        let msg = format!("{:#}", load_checkpoint(&path).unwrap_err());
        assert!(msg.contains("invalid checkpoint file"), "{msg}");

        // α / n disagreement.
        let path = dir.join("dim_ckpt.json");
        std::fs::write(
            &path,
            r#"{"format":"passcode-checkpoint-v1","solver":"dcd",
                "loss":"hinge","c":1,"seed":1,"epochs_done":0,"updates":0,
                "n":3,"d":1,"alpha":[0,0],"w_hat":[0]}"#,
        )
        .unwrap();
        let msg = format!("{:#}", load_checkpoint(&path).unwrap_err());
        assert!(msg.contains("dimension mismatch"), "{msg}");

        // Missing file: error, not panic.
        assert!(load_checkpoint(dir.join("nope.json")).is_err());
    }

    #[test]
    fn predict_matches_training_accuracy() {
        let cfg = RunConfig {
            dataset: "rcv1".into(),
            scale: 0.02,
            epochs: 10,
            threads: 2,
            eval_every: 0,
            ..Default::default()
        };
        let out = driver::run(&cfg).unwrap();
        let m = Model::from_run(&cfg, 1.0, out.result.w_hat.clone());
        let (_, test, _) = registry::load("rcv1", 0.02).unwrap();
        let (acc, preds) = m.predict_dataset(&test);
        assert!((acc - out.acc_what).abs() < 1e-9);
        assert_eq!(preds.len(), test.n());
        assert!(preds.iter().all(|&p| p == 1.0 || p == -1.0));
    }

    #[test]
    fn remap_save_load_roundtrip_and_corruption_errors() {
        let (tr, _, _) = registry::load("rcv1", 0.02).unwrap();
        let (_, remap) = tr.remap_features();
        let dir = std::env::temp_dir().join("passcode_remap_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("remap.json");
        save_remap(&remap, &path).unwrap();
        assert_eq!(load_remap(&path).unwrap(), remap);

        // Valid JSON, wrong schema.
        let bad = dir.join("foreign_remap.json");
        std::fs::write(&bad, "{\"hello\": 1}").unwrap();
        let msg = format!("{:#}", load_remap(&bad).unwrap_err());
        assert!(msg.contains("invalid remap file"), "{msg}");

        // Missing file: error, not panic.
        assert!(load_remap(dir.join("nope.json")).is_err());
    }

    #[test]
    fn margin_ignores_out_of_range_features() {
        let m = Model {
            w: vec![1.0, 2.0],
            loss: "hinge".into(),
            c: 1.0,
            solver: "dcd".into(),
            dataset: "t".into(),
        };
        assert_eq!(m.margin(&[0, 5], &[1.0, 100.0]), 1.0);
    }
}
