//! `passcode` — the command-line launcher.
//!
//! ```text
//! passcode train [--dataset rcv1] [--solver passcode-wild] [--threads 4]
//!                [--epochs 20] [--scale 0.1] [--loss hinge] [--c 1.0]
//!                [--config file.json] [--csv out.csv] [--aot-eval]
//! passcode datasets [--scale 1.0]         # Table 3 analog statistics
//! passcode calibrate                      # simulator cost-model probes
//! passcode experiment <table1|table2|table3|fig-a|fig-d|backward-error>
//!                [--dataset rcv1] [--scale 0.05] [--epochs 10] ...
//! passcode eval --dataset rcv1 --scale 0.05    # AOT vs native cross-check
//! passcode predict --model m.json --data f.svm [--out preds.txt]
//! ```

use anyhow::{bail, Context, Result};

use passcode::coordinator::{
    cli::Cli, config::RunConfig, driver, experiments, model_io::Model,
};
use passcode::data::registry;
use passcode::loss::Hinge;
use passcode::runtime::{Engine, Evaluator};
use passcode::simcore;
use passcode::solver::SerialDcd;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = real_main(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args)?;
    match cli.command.as_str() {
        "train" => cmd_train(&cli),
        "datasets" => cmd_datasets(&cli),
        "calibrate" => cmd_calibrate(),
        "experiment" => cmd_experiment(&cli),
        "eval" => cmd_eval(&cli),
        "predict" => cmd_predict(&cli),
        other => bail!(
            "unknown command {other:?}; see `passcode --help` banner in \
             README.md (commands: train, datasets, calibrate, experiment, \
             eval)"
        ),
    }
}

fn config_from_cli(cli: &Cli) -> Result<RunConfig> {
    let mut cfg = match cli.opt("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    if let Some(ds) = cli.positional.first() {
        cfg.dataset = ds.clone();
    }
    for (k, v) in &cli.options {
        if matches!(k.as_str(), "config" | "csv" | "save-model") {
            continue;
        }
        cfg.set(k, v).with_context(|| format!("--{k} {v}"))?;
    }
    Ok(cfg)
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let cfg = config_from_cli(cli)?;
    println!("config: {}", cfg.to_json().to_string());
    let out = driver::run(&cfg)?;
    println!(
        "epochs={} updates={} init={:.3}s train={:.3}s",
        out.result.epochs_run,
        out.result.updates,
        out.result.init_secs(),
        out.result.train_secs(),
    );
    println!(
        "P(ŵ)={:.6} gap={:.3e} acc(ŵ)={:.4} acc(w̄)={:.4}",
        out.primal_final, out.gap_final, out.acc_what, out.acc_wbar
    );
    for row in &out.metrics.rows {
        println!(
            "  epoch {:>4}  t={:>8.3}s  P={:.6}  gap={:.3e}  acc={:.4}",
            row.epoch, row.train_secs, row.primal, row.gap, row.test_acc
        );
    }
    if let Some(path) = cli.opt("csv") {
        std::fs::write(path, out.metrics.to_csv())?;
        println!("wrote {path}");
    }
    if let Some(path) = cli.opt("save-model") {
        let (_, _, c) = driver::load_data(&cfg)?;
        Model::from_run(&cfg, c, out.result.w_hat.clone()).save(path)?;
        println!("saved model to {path}");
    }
    if cfg.aot_eval {
        let engine = Engine::load_default().context(
            "load AOT artifacts (run `make artifacts` first)",
        )?;
        let (train, _, c) = driver::load_data(&cfg)?;
        let aot = Evaluator::new(&engine).eval(&train, &out.result.w_hat)?;
        println!(
            "AOT cross-check: P={:.6} acc={:.4} (platform {})",
            aot.primal(c),
            aot.accuracy(),
            engine.platform()
        );
    }
    Ok(())
}

fn cmd_datasets(cli: &Cli) -> Result<()> {
    let scale = cli.opt_parse("scale", 1.0f64)?;
    println!("{}", experiments::table3(scale)?.render());
    Ok(())
}

fn cmd_calibrate() -> Result<()> {
    println!("calibrating simulator cost model on this host...");
    let m = simcore::calibrate::measure();
    println!("  t_read          = {:.2} ns", m.t_read);
    println!("  t_write_plain   = {:.2} ns", m.t_write_plain);
    println!("  t_write_atomic  = {:.2} ns", m.t_write_atomic);
    println!("  t_lock_pair     = {:.2} ns", m.t_lock_pair);
    println!("  t_cas_retry     = {:.2} ns (derived)", m.t_cas_retry);
    println!("  t_lock_contended= {:.2} ns (derived)", m.t_lock_contended);
    Ok(())
}

fn cmd_experiment(cli: &Cli) -> Result<()> {
    let which = cli
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("table1");
    let scale = cli.opt_parse("scale", 0.05f64)?;
    let epochs = cli.opt_parse("epochs", 10usize)?;
    let dataset = cli.opt_or("dataset", "rcv1").to_string();
    let threads = cli.opt_parse("threads", 10usize)?;
    match which {
        "table1" => {
            let (t, _) = experiments::table1(scale, epochs)?;
            println!("Table 1 (rcv1 analog, {epochs} epochs):\n{}", t.render());
        }
        "table2" => {
            let (t, _) = experiments::table2(scale, epochs)?;
            println!("Table 2 (ŵ vs w̄, {epochs} epochs):\n{}", t.render());
        }
        "table3" => {
            println!("{}", experiments::table3(scale)?.render());
        }
        "fig-a" => {
            let logs = experiments::fig_convergence(
                &dataset, scale, epochs, threads, false,
            )?;
            for log in logs {
                println!("{}", log.to_csv());
            }
        }
        "fig-d" => {
            let (t, _) =
                experiments::fig_speedup(&dataset, scale, epochs, threads)?;
            println!("Speedup ({dataset}):\n{}", t.render());
        }
        "backward-error" => {
            let be = experiments::backward_error(&dataset, scale, epochs, 8)?;
            println!(
                "‖ε‖ = {:.6}  ‖ŵ‖ = {:.6}  ratio = {:.4}",
                be.eps_norm,
                be.w_norm,
                be.eps_norm / be.w_norm.max(1e-12)
            );
            println!(
                "perturbed-opt residual (ŵ): {:.3e}   unperturbed (w̄): {:.3e}",
                be.perturbed_residual, be.unperturbed_residual
            );
        }
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}

/// `passcode predict --model m.json --data file.svm` — batch scoring of
/// a LIBSVM file with a saved model (the deployment path).
fn cmd_predict(cli: &Cli) -> Result<()> {
    let model_path = cli
        .opt("model")
        .context("--model <file.json> is required")?;
    let data_path = cli.opt("data").context("--data <file.svm> is required")?;
    let model = Model::load(model_path)?;
    let ds = passcode::data::libsvm::load(data_path)?;
    let (acc, preds) = model.predict_dataset(&ds);
    println!(
        "model: loss={} c={} solver={} (trained on {})",
        model.loss, model.c, model.solver, model.dataset
    );
    println!("{} rows, accuracy {:.4}", ds.n(), acc);
    if let Some(out) = cli.opt("out") {
        let text: String = preds
            .iter()
            .map(|p| if *p > 0.0 { "+1\n" } else { "-1\n" })
            .collect();
        std::fs::write(out, text)?;
        println!("wrote predictions to {out}");
    }
    Ok(())
}

fn cmd_eval(cli: &Cli) -> Result<()> {
    let dataset = cli.opt_or("dataset", "covtype").to_string();
    let scale = cli.opt_parse("scale", 0.02f64)?;
    let epochs = cli.opt_parse("epochs", 5usize)?;
    let (train, _, c) = registry::load(&dataset, scale)?;
    let loss = Hinge::new(c);
    let r = SerialDcd::solve(
        &train,
        &loss,
        &passcode::solver::SolveOptions { epochs, ..Default::default() },
        None,
    );
    let native = passcode::eval::primal_objective(&train, &loss, &r.w_hat);
    let engine = Engine::load_default()?;
    let aot = Evaluator::new(&engine).eval(&train, &r.w_hat)?;
    println!("native P = {native:.6}");
    println!("AOT    P = {:.6} (platform {})", aot.primal(c), engine.platform());
    println!(
        "rel err  = {:.3e}",
        (aot.primal(c) - native).abs() / native.abs().max(1.0)
    );
    Ok(())
}
