//! `passcode` — the command-line launcher.
//!
//! ```text
//! passcode train [--dataset rcv1] [--solver passcode-wild] [--threads 4]
//!                [--epochs 20] [--scale 0.1] [--loss hinge] [--c 1.0]
//!                [--config file.json] [--csv out.csv] [--aot-eval]
//!                [--remap-features true]   # feature-locality remap
//!                [--probes true] [--trace-out spans.json]  # telemetry
//! passcode datasets [--scale 1.0]         # Table 3 analog statistics
//! passcode calibrate                      # simulator cost-model probes
//! passcode experiment <table1|table2|table3|fig-a|fig-d|backward-error>
//!                [--dataset rcv1] [--scale 0.05] [--epochs 10] ...
//! passcode eval --dataset rcv1 --scale 0.05    # AOT vs native cross-check
//! passcode predict --model m.json --data f.svm [--out preds.txt]
//! passcode serve [--model m.json | --dataset rcv1] [--data f.svm]
//!                [--shards 4] [--batch 64] [--batch-wait-us 200]
//! passcode replay [--dataset rcv1] [--scale 0.05] [--shards 4]
//!                [--rounds 3] [--batch 64] [--batch-wait-us 200]
//! passcode listen [--routes routes.json | --model m.json | --dataset rcv1]
//!                [--addr 127.0.0.1:8080] [--workers 4] [--for-secs 0]
//!                [--probes false]          # solver telemetry (default on)
//! passcode check [--model lock|atomic|wild] [--schedules 100] [--seed 42]
//!                [--threads 3] [--rows 9] [--features 6] [--epochs 2]
//!                [--preemptions 16] [--out report.json] [--smoke]
//! passcode dist-coord [--addr 127.0.0.1:8920] [--dataset rcv1 --scale 0.1 |
//!                --model m.json | --dim 47236] [--workers 2] [--max-lag 8]
//!                [--lease-ops 0]           # worker leases (0 = off)
//!                [--checkpoint w.json] [--checkpoint-every 4] [--for-secs 0]
//! passcode dist-work --coord 127.0.0.1:8920 [--manifest shards.json |
//!                --dataset rcv1 --scale 0.1 --workers 2] --shard 0
//!                [--solver passcode-atomic] [--threads 1] [--rounds 8]
//!                [--epochs-per-round 2] [--ckpt shard0.ckpt] [--seed 42]
//! passcode dist-sim [--workers 2] [--rounds 6] [--epochs-per-round 2]
//!                [--dataset rcv1] [--scale 0.05] [--solver passcode-atomic]
//!                [--threads 1] [--max-lag 8] [--seed 42] [--smoke]
//!                [--checkpoint w.json] [--manifest shards.json]
//!                [--chaos] [--fault-seed 42] [--faults plan.json]
//!                [--lease-ops 0]           # deterministic fault injection
//! passcode audit [--json report.json] [--baseline baseline.json]
//!                [--root .] [--smoke]   # static source audit, exits
//!                                       # nonzero on any violation
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use passcode::chk;
use passcode::coordinator::{
    cli::Cli, config::RunConfig, driver, experiments, model_io::Model,
};
use passcode::data::registry;
use passcode::data::shard::ShardManifest;
use passcode::dist::{
    run_sim, DistClient, DistCoordinator, DistWorker, FaultPlan, MergeConfig,
    SimConfig, WorkerConfig,
};
use passcode::loss::{Hinge, LossKind};
use passcode::net::{Router, RouteSpec, RoutesConfig, Server, ServerConfig};
use passcode::runtime::{Engine, Evaluator};
use passcode::serve::{self, ReplayConfig, ServeConfig, ServeEngine};
use passcode::simcore;
use passcode::solver::{lookup, MemoryModel, Solver, SolveOptions};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = real_main(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args)?;
    match cli.command.as_str() {
        "train" => cmd_train(&cli),
        "datasets" => cmd_datasets(&cli),
        "calibrate" => cmd_calibrate(),
        "experiment" => cmd_experiment(&cli),
        "eval" => cmd_eval(&cli),
        "predict" => cmd_predict(&cli),
        "serve" => cmd_serve(&cli),
        "replay" => cmd_replay(&cli),
        "listen" => cmd_listen(&cli),
        "check" => cmd_check(&cli),
        "dist-coord" => cmd_dist_coord(&cli),
        "dist-work" => cmd_dist_work(&cli),
        "dist-sim" => cmd_dist_sim(&cli),
        "audit" => cmd_audit(&cli),
        other => bail!("unknown command {other:?}\n\n{}", Cli::usage()),
    }
}

/// Parse `--key`, attaching the usage listing on malformed values so a
/// typo'd `--shards x` prints the offending flag plus the command list
/// instead of a bare error bubble-up.
fn flag<T: std::str::FromStr>(cli: &Cli, key: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    cli.opt_parse(key, default)
        .map_err(|e| anyhow::anyhow!("{e:#}\n\n{}", Cli::usage()))
}

fn config_from_cli(cli: &Cli) -> Result<RunConfig> {
    let mut cfg = match cli.opt("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    if let Some(ds) = cli.positional.first() {
        cfg.dataset = ds.clone();
    }
    for (k, v) in &cli.options {
        let launcher_only =
            matches!(k.as_str(), "config" | "csv" | "save-model" | "probes" | "trace-out");
        if launcher_only {
            continue;
        }
        cfg.set(k, v).with_context(|| format!("--{k} {v}"))?;
    }
    Ok(cfg)
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let cfg = config_from_cli(cli)?;
    // --trace-out implies probes: dumping an empty recorder would be
    // a silently useless file.
    let probes = flag(cli, "probes", false)? || cli.opt("trace-out").is_some();
    passcode::obs::set_probes_enabled(probes);
    println!("config: {}", cfg.to_json());
    let out = driver::run(&cfg)?;
    println!(
        "epochs={} updates={} init={:.3}s train={:.3}s",
        out.result.epochs_run,
        out.result.updates,
        out.result.init_secs(),
        out.result.train_secs(),
    );
    println!(
        "P(ŵ)={:.6} gap={:.3e} acc(ŵ)={:.4} acc(w̄)={:.4}",
        out.primal_final, out.gap_final, out.acc_what, out.acc_wbar
    );
    for row in &out.metrics.rows {
        println!(
            "  epoch {:>4}  t={:>8.3}s  P={:.6}  gap={:.3e}  acc={:.4}",
            row.epoch, row.train_secs, row.primal, row.gap, row.test_acc
        );
    }
    if let Some(path) = cli.opt("csv") {
        std::fs::write(path, out.metrics.to_csv())?;
        println!("wrote {path}");
    }
    if let Some(path) = cli.opt("save-model") {
        let (_, _, c) = driver::load_data(&cfg)?;
        Model::from_run(&cfg, c, out.result.w_hat.clone()).save(path)?;
        println!("saved model to {path}");
    }
    if cfg.aot_eval {
        let engine = Engine::load_default().context(
            "load AOT artifacts (run `make artifacts` first)",
        )?;
        let (train, _, c) = driver::load_data(&cfg)?;
        let aot = Evaluator::new(&engine).eval(&train, &out.result.w_hat)?;
        println!(
            "AOT cross-check: P={:.6} acc={:.4} (platform {})",
            aot.primal(c),
            aot.accuracy(),
            engine.platform()
        );
    }
    if let Some(path) = cli.opt("trace-out") {
        let recorder = passcode::obs::recorder();
        std::fs::write(path, recorder.to_json().to_pretty())
            .with_context(|| format!("write trace {path}"))?;
        println!(
            "wrote {path} ({} spans, {} evicted)",
            recorder.len(),
            recorder.dropped()
        );
    }
    Ok(())
}

fn cmd_datasets(cli: &Cli) -> Result<()> {
    let scale = cli.opt_parse("scale", 1.0f64)?;
    println!("{}", experiments::table3(scale)?.render());
    Ok(())
}

fn cmd_calibrate() -> Result<()> {
    println!("calibrating simulator cost model on this host...");
    let m = simcore::calibrate::measure();
    println!("  t_read          = {:.2} ns", m.t_read);
    println!("  t_write_plain   = {:.2} ns", m.t_write_plain);
    println!("  t_write_atomic  = {:.2} ns", m.t_write_atomic);
    println!("  t_lock_pair     = {:.2} ns", m.t_lock_pair);
    println!("  t_cas_retry     = {:.2} ns (derived)", m.t_cas_retry);
    println!("  t_lock_contended= {:.2} ns (derived)", m.t_lock_contended);
    Ok(())
}

fn cmd_experiment(cli: &Cli) -> Result<()> {
    let which = cli
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("table1");
    let scale = cli.opt_parse("scale", 0.05f64)?;
    let epochs = cli.opt_parse("epochs", 10usize)?;
    let dataset = cli.opt_or("dataset", "rcv1").to_string();
    let threads = cli.opt_parse("threads", 10usize)?;
    match which {
        "table1" => {
            let (t, _) = experiments::table1(scale, epochs)?;
            println!("Table 1 (rcv1 analog, {epochs} epochs):\n{}", t.render());
        }
        "table2" => {
            let (t, _) = experiments::table2(scale, epochs)?;
            println!("Table 2 (ŵ vs w̄, {epochs} epochs):\n{}", t.render());
        }
        "table3" => {
            println!("{}", experiments::table3(scale)?.render());
        }
        "fig-a" => {
            let logs = experiments::fig_convergence(
                &dataset, scale, epochs, threads, false,
            )?;
            for log in logs {
                println!("{}", log.to_csv());
            }
        }
        "fig-d" => {
            let (t, _) =
                experiments::fig_speedup(&dataset, scale, epochs, threads)?;
            println!("Speedup ({dataset}):\n{}", t.render());
        }
        "backward-error" => {
            let be = experiments::backward_error(&dataset, scale, epochs, 8)?;
            println!(
                "‖ε‖ = {:.6}  ‖ŵ‖ = {:.6}  ratio = {:.4}",
                be.eps_norm,
                be.w_norm,
                be.eps_norm / be.w_norm.max(1e-12)
            );
            println!(
                "perturbed-opt residual (ŵ): {:.3e}   unperturbed (w̄): {:.3e}",
                be.perturbed_residual, be.unperturbed_residual
            );
        }
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}

/// `passcode predict --model m.json --data file.svm` — batch scoring of
/// a LIBSVM file with a saved model (the deployment path).
fn cmd_predict(cli: &Cli) -> Result<()> {
    let model_path = cli
        .opt("model")
        .context("--model <file.json> is required")?;
    let data_path = cli.opt("data").context("--data <file.svm> is required")?;
    let model = Model::load(model_path)?;
    let ds = passcode::data::libsvm::load(data_path)?;
    let (acc, preds) = model.predict_dataset(&ds);
    println!(
        "model: loss={} c={} solver={} (trained on {})",
        model.loss, model.c, model.solver, model.dataset
    );
    println!("{} rows, accuracy {:.4}", ds.n(), acc);
    if let Some(out) = cli.opt("out") {
        let text: String = preds
            .iter()
            .map(|p| if *p > 0.0 { "+1\n" } else { "-1\n" })
            .collect();
        std::fs::write(out, text)?;
        println!("wrote predictions to {out}");
    }
    Ok(())
}

/// Shared flags → [`ServeConfig`] (malformed values carry the usage
/// listing via [`flag`]).
fn serve_config_from_cli(cli: &Cli) -> Result<ServeConfig> {
    Ok(ServeConfig {
        shards: flag(cli, "shards", 4usize)?,
        max_batch: flag(cli, "batch", 64usize)?,
        max_wait: Duration::from_micros(flag(cli, "batch-wait-us", 200u64)?),
        pin_threads: flag(cli, "pin-threads", false)?,
    })
}

/// Flags `passcode serve` accepts (checked up front so typos fail loudly).
const SERVE_FLAGS: &[&str] = &[
    "model", "dataset", "scale", "epochs", "threads", "solver", "loss", "c",
    "seed", "data", "shards", "batch", "batch-wait-us", "pin-threads",
];

/// Flags `passcode replay` accepts.
const REPLAY_FLAGS: &[&str] = &[
    "dataset", "scale", "shards", "epochs", "threads", "rounds",
    "online-epochs", "batch", "batch-wait-us", "pin-threads", "seed",
];

/// Flags `passcode listen` accepts.
const LISTEN_FLAGS: &[&str] = &[
    "routes", "addr", "workers", "for-secs", "model", "dataset", "scale",
    "epochs", "threads", "seed", "shards", "batch", "batch-wait-us",
    "pin-threads", "probes",
];

/// Flags `passcode check` accepts.
const CHECK_FLAGS: &[&str] = &[
    "model", "schedules", "threads", "rows", "features", "epochs", "seed",
    "preemptions", "out", "smoke",
];

/// `passcode check` — the in-crate memory-model checker
/// ([`passcode::chk`]): run the production update kernels over
/// instrumented shared state under seeded bounded-preemption schedules,
/// race-check each trace with vector clocks, and measure the staleness
/// τ plus the Theorem-3 backward-error ratio.  Any violation prints its
/// replaying schedule seed and exits nonzero.
fn cmd_check(cli: &Cli) -> Result<()> {
    cli.check_flags(CHECK_FLAGS)?;
    let base = chk::CheckConfig::default();
    // --smoke is CI-sized: a dozen schedules per model still covers the
    // three invariants (Wild races on every multi-threaded schedule).
    let schedules = if cli.opt("smoke").is_some() {
        12
    } else {
        base.schedules
    };
    let cfg = chk::CheckConfig {
        threads: flag(cli, "threads", base.threads)?,
        rows: flag(cli, "rows", base.rows)?,
        features: flag(cli, "features", base.features)?,
        epochs: flag(cli, "epochs", base.epochs)?,
        schedules: flag(cli, "schedules", schedules)?,
        seed: flag(cli, "seed", base.seed)?,
        preemption_bound: flag(cli, "preemptions", base.preemption_bound)?,
        ..base
    };
    let report = match cli.opt("model") {
        Some(name) => {
            let m = MemoryModel::parse(name).with_context(|| {
                format!("unknown memory model {name:?} (lock|atomic|wild)")
            })?;
            chk::run_check_models(&cfg, &[m])
        }
        None => chk::run_check(&cfg),
    };
    print!("{}", report.render());
    if let Some(path) = cli.opt("out") {
        std::fs::write(path, report.to_json().to_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("report written to {path}");
    }
    if !report.ok {
        bail!("memory-model check detected violations (replay seeds above)");
    }
    Ok(())
}

/// Flags `passcode audit` accepts.
const AUDIT_FLAGS: &[&str] = &["json", "baseline", "smoke", "root"];

/// `passcode audit` — the static analyzer over the crate's own sources
/// ([`passcode::audit`]): atomic-ordering allowlists, lock-discipline
/// containment, hot-path allocation freedom, unsafe containment, probe
/// gating, and cross-file wire/metric consistency.  Complements
/// `passcode check`: the checker explores runtime schedules, the audit
/// pins the source-level invariants those schedules rely on.  Any
/// non-baselined finding exits nonzero.
fn cmd_audit(cli: &Cli) -> Result<()> {
    cli.check_flags(AUDIT_FLAGS)?;
    let cfg = passcode::audit::AuditConfig {
        root: PathBuf::from(cli.opt_or("root", ".")),
        smoke: cli.opt("smoke").is_some(),
    };
    let (files_scanned, findings) = passcode::audit::run_audit(&cfg)?;
    let baseline = match cli.opt("baseline") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading baseline {path}"))?;
            let json = passcode::util::Json::parse(&text)
                .with_context(|| format!("parsing baseline {path}"))?;
            Some(passcode::audit::AuditReport::from_json(&json)?)
        }
        None => None,
    };
    let report =
        passcode::audit::AuditReport::new(files_scanned, findings, baseline.as_ref());
    print!("{}", report.render());
    if let Some(path) = cli.opt("json") {
        std::fs::write(path, report.to_json().to_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("report written to {path}");
    }
    if !report.ok {
        bail!("static audit detected violations (findings above)");
    }
    Ok(())
}

/// Flags `passcode dist-coord` accepts.
const DIST_COORD_FLAGS: &[&str] = &[
    "addr", "http-workers", "dim", "model", "dataset", "scale", "workers",
    "max-lag", "lease-ops", "checkpoint", "checkpoint-every", "loss", "c",
    "for-secs",
];

/// Flags `passcode dist-work` accepts.
const DIST_WORK_FLAGS: &[&str] = &[
    "coord", "manifest", "shard", "dataset", "scale", "workers", "solver",
    "threads", "epochs-per-round", "rounds", "seed", "ckpt", "loss", "c",
];

/// Flags `passcode dist-sim` accepts.
const DIST_SIM_FLAGS: &[&str] = &[
    "dataset", "scale", "workers", "rounds", "epochs-per-round", "solver",
    "threads", "max-lag", "seed", "checkpoint", "manifest", "smoke",
    "chaos", "fault-seed", "faults", "lease-ops",
];

/// `passcode dist-coord` — the distributed merge coordinator: a
/// [`passcode::net::Server`] whose only live plane is `/v1/dist/*`
/// (plus `/metrics`, `/v1/stats`, `/healthz`), applying the
/// bounded-staleness Hybrid-DCA merge to pushed worker deltas.
fn cmd_dist_coord(cli: &Cli) -> Result<()> {
    cli.check_flags(DIST_COORD_FLAGS)?;
    let loss = LossKind::parse(cli.opt_or("loss", "hinge"))?;
    let mut c = flag(cli, "c", 1.0f64)?;
    // Initial w: a saved model, a registry dataset's dimension (C comes
    // with it), or an explicit --dim for manifest-driven workers.
    let (w, dataset) = match (cli.opt("model"), cli.opt("dataset")) {
        (Some(path), _) => {
            let m = Model::load(path)?;
            c = m.c;
            (m.w, m.dataset)
        }
        (None, Some(name)) => {
            let (train, _, reg_c) = registry::load(name, flag(cli, "scale", 0.1f64)?)?;
            if cli.opt("c").is_none() {
                c = reg_c;
            }
            (vec![0.0; train.d()], name.to_string())
        }
        (None, None) => {
            let dim: usize = cli
                .opt_parse("dim", 0usize)
                .map_err(|e| anyhow::anyhow!("{e:#}\n\n{}", Cli::usage()))?;
            ensure!(
                dim > 0,
                "need an initial w: --model m.json, --dataset <name>, or --dim <d>\n\n{}",
                Cli::usage()
            );
            (vec![0.0; dim], "dist".to_string())
        }
    };
    let cfg = MergeConfig {
        workers: flag(cli, "workers", 2usize)?,
        max_lag: flag(cli, "max-lag", 8u64)?,
        lease_ops: flag(cli, "lease-ops", 0u64)?,
        record_trace: false,
        checkpoint: cli.opt("checkpoint").map(PathBuf::from),
        checkpoint_every: flag(cli, "checkpoint-every", 4u64)?,
        loss,
        c,
        dataset,
    };
    let for_secs = flag(cli, "for-secs", 0u64)?;
    println!(
        "dist-coord: d = {}, K = {}, max-lag = {}, lease-ops = {}, checkpoint = {:?}",
        w.len(),
        cfg.workers,
        cfg.max_lag,
        cfg.lease_ops,
        cfg.checkpoint,
    );
    let coord = Arc::new(DistCoordinator::new(w, cfg));
    let server = Server::start(
        Router::empty().with_dist(Arc::clone(&coord)),
        &ServerConfig {
            addr: cli.opt_or("addr", "127.0.0.1:8920").to_string(),
            workers: flag(cli, "http-workers", 4usize)?,
            // Push bodies are ~8·d bytes; leave headroom well past the
            // scoring plane's 4 MB default.
            max_body: 256 << 20,
            ..Default::default()
        },
    )?;
    println!("coordinating on http://{}", server.addr());
    println!("  POST /v1/dist/push_delta   GET /v1/dist/pull_w   POST /v1/dist/heartbeat   GET /v1/dist/stats   GET /metrics");
    if for_secs == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(for_secs));
    println!("final stats: {}", coord.stats_json());
    coord.checkpoint_now()?;
    server.shutdown();
    Ok(())
}

/// `passcode dist-work` — one distributed worker process: load (only)
/// its shard, run warm-started local PASSCoDe rounds, and exchange
/// deltas with the coordinator at `--coord`.  Restarting with the same
/// `--ckpt` rejoins after a crash.
fn cmd_dist_work(cli: &Cli) -> Result<()> {
    cli.check_flags(DIST_WORK_FLAGS)?;
    let coord_addr: std::net::SocketAddr = cli
        .opt("coord")
        .context("--coord <host:port> is required")?
        .parse()
        .context("--coord must be host:port")?;
    let manifest = match cli.opt("manifest") {
        Some(path) => ShardManifest::load(path)?,
        None => ShardManifest::for_registry(
            cli.opt_or("dataset", "rcv1"),
            flag(cli, "scale", 0.1f64)?,
            flag(cli, "workers", 2usize)?,
        )?,
    };
    let id = flag(cli, "shard", 0usize)?;
    let shard = manifest.load_shard(id)?;
    let cfg = WorkerConfig {
        id: id as u64,
        solver: cli.opt_or("solver", "passcode-atomic").to_string(),
        loss: LossKind::parse(cli.opt_or("loss", "hinge"))?,
        c: flag(cli, "c", manifest.c)?,
        threads: flag(cli, "threads", 1usize)?,
        epochs_per_round: flag(cli, "epochs-per-round", 2usize)?,
        rounds: flag(cli, "rounds", 8usize)?,
        seed: flag(cli, "seed", 42u64)?,
        checkpoint: cli.opt("ckpt").map(PathBuf::from),
        // Announce liveness + the shard range so a lease-mode
        // coordinator can reassign it if this process dies (a no-op
        // echo when the coordinator runs without leases).
        heartbeat: true,
        ranges: vec![(
            manifest.shards[id].start as u64,
            manifest.shards[id].end as u64,
        )],
    };
    println!(
        "dist-work {}: shard rows {}..{} of {} ({} rows), coordinator {}",
        id,
        manifest.shards[id].start,
        manifest.shards[id].end,
        manifest.dataset,
        shard.n(),
        coord_addr,
    );
    let mut client = DistClient::new(coord_addr);
    let mut worker = DistWorker::new(&shard, cfg)?;
    let report = worker.run(&mut client, None)?;
    println!(
        "done{}: {} rounds ({} accepted, {} resyncs), {} epochs, {} updates",
        if report.revoked { " (lease revoked — contribution rolled back)" } else { "" },
        report.rounds, report.accepted, report.resyncs, report.epochs, report.updates,
    );
    println!("coordinator stats: {}", client.stats()?);
    Ok(())
}

/// `passcode dist-sim` — the whole distributed tier in one process:
/// shard the dataset, boot a loopback coordinator, race N worker
/// threads through it, and score the merged model.  `--smoke` is the
/// CI shape (tiny dataset, 3 rounds).  `--chaos` (or an explicit
/// `--faults plan.json`) injects seeded transport faults and verifies
/// the Σ-invariant survived them.
fn cmd_dist_sim(cli: &Cli) -> Result<()> {
    cli.check_flags(DIST_SIM_FLAGS)?;
    let smoke = cli.opt("smoke").is_some();
    // --faults loads an explicit passcode-faults-v1 plan; bare --chaos
    // takes the built-in moderate plan seeded by --fault-seed.  Either
    // switches the sim to the deterministic chaos driver.
    let chaos = match cli.opt("faults") {
        Some(path) => Some(FaultPlan::load(std::path::Path::new(path))?),
        None if cli.opt("chaos").is_some() => {
            Some(FaultPlan::moderate(flag(cli, "fault-seed", 42u64)?))
        }
        None => None,
    };
    let base = SimConfig::default();
    let cfg = SimConfig {
        dataset: cli.opt_or("dataset", &base.dataset).to_string(),
        scale: flag(cli, "scale", if smoke { 0.02 } else { base.scale })?,
        workers: flag(cli, "workers", base.workers)?,
        rounds: flag(cli, "rounds", if smoke { 3 } else { base.rounds })?,
        epochs_per_round: flag(
            cli,
            "epochs-per-round",
            if smoke { 1 } else { base.epochs_per_round },
        )?,
        solver: cli.opt_or("solver", &base.solver).to_string(),
        loss: base.loss,
        threads_per_worker: flag(cli, "threads", base.threads_per_worker)?,
        max_lag: flag(cli, "max-lag", base.max_lag)?,
        seed: flag(cli, "seed", base.seed)?,
        checkpoint: cli.opt("checkpoint").map(PathBuf::from),
        manifest_out: cli.opt("manifest").map(PathBuf::from),
        lease_ops: flag(cli, "lease-ops", 0u64)?,
        chaos,
    };
    println!(
        "dist-sim: {}@{} across {} workers × {} rounds × {} epochs (max-lag {}{})",
        cfg.dataset,
        cfg.scale,
        cfg.workers,
        cfg.rounds,
        cfg.epochs_per_round,
        cfg.max_lag,
        match &cfg.chaos {
            Some(p) => format!(", chaos seed {}", p.seed),
            None => String::new(),
        },
    );
    let report = run_sim(&cfg)?;
    for (i, w) in report.workers.iter().enumerate() {
        println!(
            "  worker {i}: {} rounds ({} accepted, {} resyncs), {} epochs, {} updates",
            w.rounds, w.accepted, w.resyncs, w.epochs, w.updates,
        );
    }
    println!(
        "merge epoch {} ({} merges, {} rejects), backward-error ratio {:.3e}",
        report.merge_epoch, report.merges, report.rejects, report.backward_error_ratio,
    );
    println!(
        "P(w) = {:.6}  gap = {:.3e}  test acc = {:.4}",
        report.primal, report.gap, report.test_accuracy,
    );
    println!("dist metrics:");
    for line in &report.dist_metrics {
        println!("  {line}");
    }
    ensure!(
        !report.dist_metrics.is_empty(),
        "no passcode_dist_* metrics after a sim run"
    );
    ensure!(
        report.merge_epoch > 0 && report.w.iter().all(|v| v.is_finite()),
        "simulation produced no merges or a non-finite model"
    );
    if cfg.chaos.is_some() {
        println!(
            "chaos: {} faults injected, {} rejects, {} reassigns, {} merge-trace entries",
            report.fault_events.len(),
            report.rejects,
            report.reassigns,
            report.merge_trace.len(),
        );
        ensure!(
            report
                .dist_metrics
                .iter()
                .any(|l| l.contains("passcode_dist_fault_injected_total")),
            "chaos run exported no passcode_dist_fault_injected_total metrics"
        );
        ensure!(
            !report.fault_events.is_empty(),
            "chaos run injected no faults — the plan never fired"
        );
        // Single-threaded local solves have no asynchronous write loss,
        // so any Σ-invariant drift there is a merge/rollback bug; with
        // threads the residual legitimately absorbs Theorem-3 loss.
        if cfg.threads_per_worker == 1 {
            ensure!(
                report.sigma_residual < 1e-6,
                "sigma-invariant BROKEN: |w - X^T a| / |w| = {:.3e}",
                report.sigma_residual,
            );
        }
        println!("sigma-invariant OK (residual {:.3e})", report.sigma_residual);
    }
    Ok(())
}

/// `passcode serve` — stand up the online scoring stack around a model
/// (loaded from `--model`, or trained fresh from `--dataset`) and stream
/// scoring traffic through it from `--data <file.svm>` (or stdin), then
/// report QPS + latency percentiles.
fn cmd_serve(cli: &Cli) -> Result<()> {
    cli.check_flags(SERVE_FLAGS)?;
    let (model, alpha) = match cli.opt("model") {
        Some(path) => (Model::load(path)?, None),
        None => {
            // Only the training-relevant flags feed the RunConfig here;
            // serve flags (--shards, --batch, ...) are not config keys.
            let mut cfg =
                RunConfig { eval_every: 0, scale: 0.05, ..Default::default() };
            for key in
                ["dataset", "scale", "epochs", "threads", "solver", "loss",
                 "c", "seed"]
            {
                if let Some(v) = cli.opt(key) {
                    cfg.set(key, v).with_context(|| format!("--{key} {v}"))?;
                }
            }
            println!("no --model given; training one: {}", cfg.to_json());
            let (model, result) = driver::train_model(&cfg)?;
            (model, Some(result.alpha))
        }
    };
    let scfg = serve_config_from_cli(cli)?;
    println!(
        "serving `{}` model (d = {}) on {} shards (batch ≤ {}, wait {:?})",
        model.dataset,
        model.w.len(),
        scfg.shards,
        scfg.max_batch,
        scfg.max_wait,
    );
    let engine = ServeEngine::start(model, alpha, &scfg);

    // Traffic source: a LIBSVM file, or stdin lines in the same format.
    let ds = match cli.opt("data") {
        Some(path) => passcode::data::libsvm::load(path)?,
        None => {
            println!("reading LIBSVM lines from stdin (EOF ends)...");
            passcode::data::libsvm::parse_reader(
                std::io::stdin(),
                "stdin",
                0,
            )?
        }
    };
    let mut tickets = Vec::with_capacity(ds.n());
    for i in 0..ds.n() {
        // rows are folded (x = y·ẋ): serve the raw features
        let (idx, raw) = ds.raw_row(i);
        tickets.push((engine.submit(idx, raw), ds.y[i]));
    }
    let mut correct = 0usize;
    for (t, y) in tickets {
        let p = t.wait();
        if p.label == y {
            correct += 1;
        }
    }
    println!(
        "scored {} rows, accuracy {:.4}",
        ds.n(),
        correct as f64 / ds.n().max(1) as f64
    );
    println!("{}", engine.shutdown().render());
    Ok(())
}

/// `passcode replay` — replay a held-out split through the batcher /
/// scorer stack while the online trainer hot-swaps retrained models
/// mid-stream; reports QPS and p50/p95/p99 latency.
fn cmd_replay(cli: &Cli) -> Result<()> {
    cli.check_flags(REPLAY_FLAGS)?;
    let scfg = serve_config_from_cli(cli)?;
    let cfg = ReplayConfig {
        dataset: cli.opt_or("dataset", "rcv1").to_string(),
        scale: flag(cli, "scale", 0.05f64)?,
        shards: scfg.shards,
        train_epochs: flag(cli, "epochs", 10usize)?,
        train_threads: flag(cli, "threads", 2usize)?,
        online_rounds: flag(cli, "rounds", 3usize)?,
        online_epochs: flag(cli, "online-epochs", 2usize)?,
        max_batch: scfg.max_batch,
        max_wait: scfg.max_wait,
        pin_threads: scfg.pin_threads,
        seed: flag(cli, "seed", 42u64)?,
    };
    println!(
        "replaying {}@{} through {} shards ({} online rounds)...",
        cfg.dataset, cfg.scale, cfg.shards, cfg.online_rounds
    );
    let report = serve::replay(&cfg)?;
    print!("{}", report.render());
    Ok(())
}

/// `passcode listen` — the HTTP front end: bring up one engine per
/// configured route and serve `POST /v1/score` plus the admin plane
/// (`/v1/stats`, `/v1/models/{route}/publish`, `/healthz`) until
/// interrupted (or for `--for-secs` seconds, then report per route).
fn cmd_listen(cli: &Cli) -> Result<()> {
    cli.check_flags(LISTEN_FLAGS)?;
    // Every flag parses before any training/binding work starts, so a
    // malformed value fails in milliseconds, not after model bring-up.
    let for_secs = flag(cli, "for-secs", 0u64)?;
    // Telemetry is on by default for the long-running server (the
    // probes are cheap and /metrics is useless without them); opt out
    // with --probes false.  Enabled before Router::start so startup
    // dataset training and online rounds report too.
    passcode::obs::set_probes_enabled(flag(cli, "probes", true)?);
    let routes_cfg = match cli.opt("routes") {
        Some(path) => {
            // With a config file the single-route flags have no effect;
            // reject them instead of silently ignoring them.
            cli.check_flags(&["routes", "addr", "workers", "for-secs", "probes"])
                .map_err(|_| {
                    anyhow::anyhow!(
                        "--routes provides the per-route settings; drop the \
                         single-route flags (--model/--dataset/--shards/...)\
                         \n\n{}",
                        Cli::usage()
                    )
                })?;
            RoutesConfig::from_file(path)?
        }
        None => {
            // Single-route fallback: --model file, or train from
            // --dataset (rcv1 analog by default) at startup.
            let mut spec = RouteSpec {
                scale: flag(cli, "scale", 0.05f64)?,
                epochs: flag(cli, "epochs", 10usize)?,
                threads: flag(cli, "threads", 2usize)?,
                seed: flag(cli, "seed", 42u64)?,
                serve: serve_config_from_cli(cli)?,
                ..Default::default()
            };
            match (cli.opt("model"), cli.opt("dataset")) {
                (Some(_), Some(_)) => bail!(
                    "--model and --dataset are mutually exclusive (a route \
                     serves a saved model or trains one, not both)\n\n{}",
                    Cli::usage()
                ),
                (Some(m), None) => spec.model = Some(m.to_string()),
                (None, ds) => {
                    spec.dataset = Some(ds.unwrap_or("rcv1").to_string());
                }
            }
            RoutesConfig { routes: vec![spec] }
        }
    };
    let scfg = ServerConfig {
        addr: cli.opt_or("addr", "127.0.0.1:8080").to_string(),
        workers: flag(cli, "workers", 4usize)?,
        ..Default::default()
    };
    println!(
        "bringing up {} route(s): {}",
        routes_cfg.routes.len(),
        routes_cfg
            .routes
            .iter()
            .map(|r| r.name.as_str())
            .collect::<Vec<_>>()
            .join(", ")
    );
    let server = Server::start(Router::start(&routes_cfg)?, &scfg)?;
    println!("listening on http://{}", server.addr());
    println!(
        "  POST /v1/score   POST /v1/models/{{route}}/publish   \
         GET /v1/stats   GET /healthz"
    );
    println!("  GET /metrics (Prometheus text)   GET /v1/trace (flight recorder)");
    if for_secs == 0 {
        // Serve until the process is killed.
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(for_secs));
    for (name, report) in server.shutdown() {
        println!("route {name}:\n{}", report.render());
    }
    Ok(())
}

fn cmd_eval(cli: &Cli) -> Result<()> {
    let dataset = cli.opt_or("dataset", "covtype").to_string();
    let scale = cli.opt_parse("scale", 0.02f64)?;
    let epochs = cli.opt_parse("epochs", 5usize)?;
    let (train, _, c) = registry::load(&dataset, scale)?;
    let loss = Hinge::new(c);
    let solver = lookup("dcd")?;
    let mut session = solver.session(
        &train,
        LossKind::Hinge,
        c,
        SolveOptions { epochs, ..Default::default() },
    )?;
    session.run_epochs(epochs)?;
    let r = session.into_result();
    let native = passcode::eval::primal_objective(&train, &loss, &r.w_hat);
    let engine = Engine::load_default()?;
    let aot = Evaluator::new(&engine).eval(&train, &r.w_hat)?;
    println!("native P = {native:.6}");
    println!("AOT    P = {:.6} (platform {})", aot.primal(c), engine.platform());
    println!(
        "rel err  = {:.3e}",
        (aot.primal(c) - native).abs() / native.abs().max(1.0)
    );
    Ok(())
}
