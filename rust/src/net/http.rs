//! Minimal HTTP/1.1 wire protocol: request parsing and response
//! serialization over any `Read`/`Write` pair (no new dependencies —
//! the offline image vendors no hyper/tiny_http).
//!
//! Covers exactly what the `net` front end needs: request line +
//! headers + `Content-Length` bodies, keep-alive semantics
//! (HTTP/1.1 persistent by default, `Connection: close` honored),
//! and bounded sizes so a misbehaving client cannot balloon memory.
//! Chunked transfer encoding is intentionally rejected (413/501-style
//! errors) rather than half-implemented.

use anyhow::{bail, ensure, Context, Result};
use std::io::{BufRead, Read as _, Write};
use std::time::{Duration, Instant};

use crate::util::Json;

/// Hard cap on a single header line (start line included).
const MAX_LINE: usize = 8 * 1024;
/// Hard cap on header count per request.
const MAX_HEADERS: usize = 64;

/// Marker error: the connection hit its read timeout while completely
/// idle at a request boundary (no bytes of a next request consumed).
/// The caller may safely keep waiting on the same connection; any
/// other timeout means a request was abandoned mid-wire and the
/// connection must be closed (resuming would desynchronize parsing).
#[derive(Debug)]
pub struct IdleTimeout;

impl std::fmt::Display for IdleTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("idle read timeout at request boundary")
    }
}

impl std::error::Error for IdleTimeout {}

/// Marker error: a request body exceeded the configured cap (the
/// server answers `413 Payload Too Large`, not a generic 400).
#[derive(Debug)]
pub struct PayloadTooLarge;

impl std::fmt::Display for PayloadTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("payload exceeds the configured cap")
    }
}

impl std::error::Error for PayloadTooLarge {}

/// Marker error: the client stalled past the request deadline after
/// the request had started (the server answers `408 Request Timeout`).
#[derive(Debug)]
pub struct RequestTimeout;

impl std::fmt::Display for RequestTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("request deadline exceeded")
    }
}

impl std::error::Error for RequestTimeout {}

/// One parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Upper-case method token (`GET`, `POST`, ...).
    pub method: String,
    /// Decoded path without the query string (`/v1/score`).
    pub path: String,
    /// `key=value` pairs from the query string, in order.
    pub query: Vec<(String, String)>,
    /// Headers with lower-cased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes (empty when no `Content-Length`).
    pub body: Vec<u8>,
    /// True for an `HTTP/1.0` request (keep-alive must be explicit).
    pub http10: bool,
}

impl Request {
    /// First header value for `name` (case-insensitive), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// First query parameter `name`, if present.
    pub fn query(&self, name: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Whether the connection should stay open after the response:
    /// HTTP/1.1 defaults to keep-alive unless the client sent
    /// `Connection: close`; HTTP/1.0 defaults to close unless the
    /// client sent `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        let conn = self.header("connection");
        if self.http10 {
            conn.map(|v| v.eq_ignore_ascii_case("keep-alive"))
                .unwrap_or(false)
        } else {
            !conn
                .map(|v| v.eq_ignore_ascii_case("close"))
                .unwrap_or(false)
        }
    }
}

/// Whether an I/O error is a socket read-timeout expiry.
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read one CRLF- (or LF-) terminated line, bounded by [`MAX_LINE`].
///
/// The socket's short read timeout is the caller's poll point, not a
/// hard per-byte budget: once a request has started (`deadline` is
/// set), timeouts are retried until the request deadline so a slow
/// client (TCP retransmit, `Expect: 100-continue` pause) is not 400'd.
/// A timeout *before* any byte of the request (`deadline` still
/// `None`) surfaces as [`IdleTimeout`] — the connection is idle at a
/// request boundary and the caller may safely keep waiting.  The
/// first consumed byte arms `deadline`.
fn read_line<R: BufRead>(
    r: &mut R,
    deadline: &mut Option<Instant>,
    timeout: Duration,
) -> Result<Option<String>> {
    let mut buf = Vec::with_capacity(80);
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                // EOF: clean only if nothing was read yet.
                if buf.is_empty() && deadline.is_none() {
                    return Ok(None);
                }
                bail!("connection closed mid-line");
            }
            Ok(_) => {
                if deadline.is_none() {
                    *deadline = Some(Instant::now() + timeout);
                }
                if byte[0] == b'\n' {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    return Ok(Some(String::from_utf8(buf)?));
                }
                buf.push(byte[0]);
                ensure!(buf.len() <= MAX_LINE, "header line exceeds {MAX_LINE} bytes");
            }
            Err(e) if is_timeout(&e) => match *deadline {
                None => return Err(anyhow::Error::new(IdleTimeout)),
                Some(d) if Instant::now() < d => continue,
                Some(_) => {
                    return Err(anyhow::Error::new(RequestTimeout)
                        .context("request timed out mid-line"))
                }
            },
            Err(e) => return Err(e.into()),
        }
    }
}

/// `read_exact` that rides out read timeouts until `deadline`.
fn read_body<R: BufRead>(
    r: &mut R,
    buf: &mut [u8],
    deadline: Instant,
) -> Result<()> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => bail!("connection closed mid-body"),
            Ok(n) => filled += n,
            Err(e) if is_timeout(&e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow::Error::new(RequestTimeout)
                        .context("request timed out mid-body"));
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Split `path?query` and parse the query string (no percent-decoding:
/// route names and numeric parameters never need it).
fn parse_target(target: &str) -> (String, Vec<(String, String)>) {
    match target.split_once('?') {
        None => (target.to_string(), Vec::new()),
        Some((path, qs)) => {
            let query = qs
                .split('&')
                .filter(|kv| !kv.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => (kv.to_string(), String::new()),
                })
                .collect();
            (path.to_string(), query)
        }
    }
}

/// Read one request off the connection, answering
/// `Expect: 100-continue` on `w` before the body so clients like curl
/// do not stall waiting for the interim response.
///
/// Returns `Ok(None)` on clean EOF before any bytes (the keep-alive
/// peer hung up between requests); [`IdleTimeout`] on a read timeout
/// at the request boundary; errors on malformed, oversized, or
/// mid-request-stalled input (budget: `timeout` from the request's
/// first byte) — the caller answers with a 4xx and closes.
pub fn read_request<R: BufRead, W: Write>(
    r: &mut R,
    w: &mut W,
    max_body: usize,
    timeout: Duration,
) -> Result<Option<Request>> {
    let mut deadline: Option<Instant> = None;
    let start = match read_line(r, &mut deadline, timeout)? {
        None => return Ok(None),
        Some(line) => line,
    };
    let mut parts = start.split_ascii_whitespace();
    let method = parts
        .next()
        .context("empty request line")?
        .to_ascii_uppercase();
    let target = parts.next().context("request line missing target")?;
    let version = parts.next().context("request line missing version")?;
    ensure!(
        version == "HTTP/1.1" || version == "HTTP/1.0",
        "unsupported version {version:?}"
    );
    let (path, query) = parse_target(target);
    let http10 = version == "HTTP/1.0";

    let mut headers = Vec::new();
    loop {
        let line = read_line(r, &mut deadline, timeout)?
            .context("connection closed in headers")?;
        if line.is_empty() {
            break;
        }
        ensure!(headers.len() < MAX_HEADERS, "too many headers");
        let (k, v) = line
            .split_once(':')
            .with_context(|| format!("malformed header {line:?}"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    let mut req =
        Request { method, path, query, headers, body: Vec::new(), http10 };
    if let Some(te) = req.header("transfer-encoding") {
        bail!("transfer-encoding {te:?} not supported (use Content-Length)");
    }
    if let Some(len) = req.header("content-length") {
        let len: usize = len
            .parse()
            .with_context(|| format!("bad Content-Length {len:?}"))?;
        if len > max_body {
            return Err(anyhow::Error::new(PayloadTooLarge).context(
                format!("body of {len} bytes exceeds cap {max_body}"),
            ));
        }
        if req
            .header("expect")
            .map(|v| v.eq_ignore_ascii_case("100-continue"))
            .unwrap_or(false)
        {
            // The client is holding the body back until we nod.
            w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                .and_then(|()| w.flush())
                .context("write 100 Continue")?;
        }
        let mut body = vec![0u8; len];
        let d = deadline.unwrap_or_else(|| Instant::now() + timeout);
        read_body(r, &mut body, d)?;
        req.body = body;
    }
    Ok(Some(req))
}

/// One HTTP response to serialize.
#[derive(Debug, Clone)]
pub struct Response {
    /// Status code (200, 404, ...).
    pub status: u16,
    /// `Content-Type` of `body`.
    pub content_type: &'static str,
    /// Response payload.
    pub body: Vec<u8>,
}

impl Response {
    /// A JSON response.
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.to_string().into_bytes(),
        }
    }

    /// A plain-text response.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
        }
    }

    /// A JSON error envelope (`{"error": msg}`).
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::str(msg))]))
    }

    /// Standard reason phrase for the status code.
    pub fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            413 => "Payload Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    /// Serialize onto the wire.  `keep_alive` picks the `Connection`
    /// header the server advertises back.  The whole response is
    /// assembled first and sent as one `write_all` — per-fragment
    /// writes on a `TCP_NODELAY` socket would cost a syscall (and a
    /// tiny packet) each on the hot scoring path.
    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        let mut wire = Vec::with_capacity(head.len() + self.body.len());
        wire.extend_from_slice(head.as_bytes());
        wire.extend_from_slice(&self.body);
        w.write_all(&wire)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<Option<Request>> {
        read_request(
            &mut BufReader::new(raw.as_bytes()),
            &mut Vec::new(),
            1024,
            Duration::from_secs(5),
        )
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse("GET /v1/stats?route=a&verbose HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/stats");
        assert_eq!(r.query("route"), Some("a"));
        assert_eq!(r.query("verbose"), Some(""));
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.keep_alive());
        assert!(r.body.is_empty());
    }

    #[test]
    fn parses_post_with_body_and_close() {
        let r = parse(
            "POST /v1/score HTTP/1.1\r\nContent-Length: 5\r\nConnection: close\r\n\r\nhello",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"hello");
        assert!(!r.keep_alive());
    }

    #[test]
    fn expect_100_continue_is_answered_before_body() {
        let raw = "POST /v1/score HTTP/1.1\r\nExpect: 100-continue\r\n\
                   Content-Length: 5\r\n\r\nhello";
        let mut interim = Vec::new();
        let req = read_request(
            &mut BufReader::new(raw.as_bytes()),
            &mut interim,
            1024,
            Duration::from_secs(5),
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.body, b"hello");
        assert_eq!(interim, b"HTTP/1.1 100 Continue\r\n\r\n");
        // Without the Expect header nothing interim is written.
        let mut silent = Vec::new();
        read_request(
            &mut BufReader::new(
                "POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\nok".as_bytes(),
            ),
            &mut silent,
            1024,
            Duration::from_secs(5),
        )
        .unwrap()
        .unwrap();
        assert!(silent.is_empty());
    }

    #[test]
    fn http10_defaults_to_close_unless_explicit() {
        let r = parse("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(r.http10);
        assert!(!r.keep_alive(), "HTTP/1.0 default is close");
        let r = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(r.keep_alive());
        let r = parse("GET / HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert!(!r.http10);
        assert!(r.keep_alive(), "HTTP/1.1 default is keep-alive");
    }

    #[test]
    fn bare_lf_lines_are_tolerated() {
        let r = parse("GET / HTTP/1.1\nHost: y\n\n").unwrap().unwrap();
        assert_eq!(r.header("host"), Some("y"));
    }

    #[test]
    fn clean_eof_is_none_midstream_is_error() {
        assert!(parse("").unwrap().is_none());
        assert!(parse("GET / HTTP/1.1\r\nHost").is_err());
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort").is_err());
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        assert!(parse("NOT-HTTP\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/2\r\n\r\n").is_err());
        let too_big =
            parse("POST / HTTP/1.1\r\nContent-Length: 9999\r\n\r\n").unwrap_err();
        assert!(
            too_big.downcast_ref::<PayloadTooLarge>().is_some(),
            "oversize must carry the 413 marker: {too_big:#}"
        );
        assert!(parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").is_err());
    }

    #[test]
    fn response_round_trips_on_the_wire() {
        let resp = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]));
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Connection: keep-alive"));
        assert!(text.ends_with("{\"ok\":true}"));
        let err = Response::error(404, "nope");
        assert_eq!(err.reason(), "Not Found");
    }
}
