//! The HTTP server: a `std::net::TcpListener` accept loop feeding a
//! bounded pool of worker threads, each handling keep-alive
//! connections and dispatching requests against a [`Router`].
//!
//! Endpoints:
//!
//! * `POST /v1/score` — score one or many sparse rows (JSON or LIBSVM
//!   body, see [`super::body`]); route chosen by `?route=` query
//!   parameter or the JSON `"route"` field (optional when exactly one
//!   route is configured).  Rows with labels (LIBSVM) are also fed to
//!   the route's online trainer when one is attached.
//! * `POST /v1/models/{route}/publish` — hot-swap a model file into
//!   the route's registry (body: `{"path": "model.json"}`).
//! * `GET /v1/stats` — per-route [`ThroughputReport`] JSON, including
//!   `versions_alive` and `epoch`.
//! * `GET /metrics` — Prometheus text exposition of the process-wide
//!   [`crate::obs::MetricsRegistry`]: the `passcode_train_*` solver
//!   family next to `passcode_http_*` / per-route `passcode_route_*`
//!   serving metrics, all in one scrape.
//! * `GET /v1/trace` — the [`crate::obs::FlightRecorder`] ring (recent
//!   HTTP/training spans with tid + monotonic timestamps) as JSON.
//! * `GET /healthz` — liveness plus the route list.
//! * `POST /v1/dist/push_delta`, `GET /v1/dist/pull_w`,
//!   `POST /v1/dist/heartbeat`, `GET /v1/dist/stats` — the
//!   distributed-tier merge plane (binary delta/heartbeat bodies, see
//!   [`crate::dist::protocol`]); live only when a
//!   [`crate::dist::DistCoordinator`] is attached via
//!   [`Router::with_dist`](super::router::Router::with_dist).  Pulls
//!   accept an optional `?worker=ID` so they refresh that worker's
//!   lease.
//!
//! Back-pressure: at most `queue_cap` accepted connections may be
//! waiting for a worker; beyond that the server answers `503` and
//! closes — bounded memory under accept floods, matching the bounded
//! microbatch queue behind it.

use std::collections::VecDeque;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::serve::ThroughputReport;
use crate::util::Json;

use super::body::decode_score_body;
use super::http::{
    read_request, IdleTimeout, PayloadTooLarge, Request, RequestTimeout,
    Response,
};
use super::router::{Route, Router};

/// Server shape.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address (`127.0.0.1:0` picks a free port — tests/benches).
    pub addr: String,
    /// Worker threads handling connections.
    pub workers: usize,
    /// Accepted connections allowed to wait for a worker.
    pub queue_cap: usize,
    /// Per-request body cap in bytes.
    pub max_body: usize,
    /// Requests served per connection before the server closes it
    /// (bounds how long one client can monopolize a worker).
    pub keep_alive_max: usize,
    /// How long a keep-alive connection may sit idle between requests
    /// before the server closes it and hands its worker back (a few
    /// idle sockets must not starve the whole pool).
    pub idle_timeout: Duration,
    /// Budget for receiving one request (first byte → full body); a
    /// client stalled longer than this mid-request is disconnected.
    pub request_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            queue_cap: 128,
            max_body: 4 << 20,
            keep_alive_max: 10_000,
            idle_timeout: Duration::from_secs(10),
            request_timeout: Duration::from_secs(10),
        }
    }
}

/// One queued connection: the socket, when it last completed a request
/// (the idle budget, preserved across requeues), and when it entered
/// its current queue (pop fairness — reset on every requeue so a
/// silent parked client cannot perpetually outrank fresh arrivals).
struct Conn {
    stream: TcpStream,
    idle_since: Instant,
    enqueued: Instant,
}

/// The two connection pools workers draw from.  `fresh` holds newly
/// accepted sockets and is bounded by `queue_cap`; `parked` holds idle
/// keep-alive connections rotated out by workers — kept separate so
/// a crowd of quiet keep-alive clients can never exhaust the accept
/// budget and 503 new arrivals (each parked socket still dies at
/// `idle_timeout`).
#[derive(Default)]
struct Queues {
    fresh: VecDeque<Conn>,
    parked: VecDeque<Conn>,
}

impl Queues {
    /// Pop the longest-queued connection across both pools, so a
    /// sustained accept flood cannot starve a parked connection whose
    /// client has started sending again (and vice versa).
    fn pop(&mut self) -> Option<Conn> {
        let fresh_t = self.fresh.front().map(|c| c.enqueued);
        let parked_t = self.parked.front().map(|c| c.enqueued);
        match (fresh_t, parked_t) {
            (Some(f), Some(p)) if p < f => self.parked.pop_front(),
            (Some(_), _) => self.fresh.pop_front(),
            (None, Some(_)) => self.parked.pop_front(),
            (None, None) => None,
        }
    }

    fn is_empty(&self) -> bool {
        self.fresh.is_empty() && self.parked.is_empty()
    }
}

/// Shared state between the accept loop and the workers.
struct Shared {
    router: Router,
    queue: Mutex<Queues>,
    ready: Condvar,
    stop: AtomicBool,
    cfg: ServerConfig,
}

/// A running HTTP front end.  Dropping without [`Server::shutdown`]
/// leaves threads running until the process exits — call `shutdown`
/// (tests and `passcode listen` both do).
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `cfg.addr` and start the accept loop plus `cfg.workers`
    /// worker threads serving `router`.
    pub fn start(router: Router, cfg: &ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        listener
            .set_nonblocking(true)
            .context("set listener nonblocking")?;
        let shared = Arc::new(Shared {
            router,
            queue: Mutex::new(Queues::default()),
            ready: Condvar::new(),
            stop: AtomicBool::new(false),
            cfg: cfg.clone(),
        });

        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("net-accept".into())
                .spawn(move || accept_loop(listener, &shared))
                .context("spawn accept thread")?
        };
        let workers = (0..cfg.workers.max(1))
            .map(|t| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("net-worker-{t}"))
                    .spawn(move || worker_loop(&shared))
                    .context("spawn worker thread")
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Server { addr, shared, accept: Some(accept), workers })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The router being served.
    pub fn router(&self) -> &Router {
        &self.shared.router
    }

    /// Stop accepting, finish in-flight requests, join every thread,
    /// and shut each route's engine down; per-route final reports.
    pub fn shutdown(mut self) -> Vec<(String, ThroughputReport)> {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.ready.notify_all();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Sole owner now: every thread holding a Shared clone has
        // exited, so unwrap the router out and wind the engines down.
        let shared = Arc::try_unwrap(self.shared)
            .map_err(|_| ())
            .expect("server threads joined");
        shared.router.shutdown()
    }
}

impl std::fmt::Debug for Server {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Server({}, workers={})", self.addr, self.workers.len())
    }
}

fn accept_loop(listener: TcpListener, shared: &Shared) {
    while !shared.stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                // BSD/macOS accepted sockets inherit the listener's
                // O_NONBLOCK; force blocking so read timeouts pace the
                // workers instead of instant WouldBlock busy-spins.
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let mut q = shared.queue.lock().expect("net queue poisoned");
                if q.fresh.len() >= shared.cfg.queue_cap {
                    drop(q);
                    // Shed load instead of queueing unboundedly (write
                    // timeout: a non-reading flooder must not pin the
                    // accept loop either).
                    let mut s = stream;
                    let _ = s.set_write_timeout(Some(Duration::from_secs(1)));
                    let _ = Response::error(503, "server overloaded")
                        .write_to(&mut s, false);
                } else {
                    let now = Instant::now();
                    q.fresh.push_back(Conn {
                        stream,
                        idle_since: now,
                        enqueued: now,
                    });
                    shared.ready.notify_one();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Nonblocking accept doubles as the stop-flag poll point.
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(1)),
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let conn = {
            let mut q = shared.queue.lock().expect("net queue poisoned");
            loop {
                if let Some(c) = q.pop() {
                    break c;
                }
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                let (nq, _) = shared
                    .ready
                    .wait_timeout(q, Duration::from_millis(50))
                    .expect("net queue poisoned");
                q = nq;
            }
        };
        if let Some(conn) = handle_connection(conn, shared) {
            // The connection went idle while others were waiting:
            // park it so one slow-polling client cannot pin this
            // worker (and parked idlers never crowd out fresh work).
            let mut q = shared.queue.lock().expect("net queue poisoned");
            q.parked.push_back(conn);
            shared.ready.notify_one();
        }
    }
}

/// Serve one (possibly keep-alive) connection until it closes, goes
/// over budget, or — `Some(conn)` — goes idle while other connections
/// are waiting for a worker (the caller parks it).
fn handle_connection(conn: Conn, shared: &Shared) -> Option<Conn> {
    let Conn { stream, mut idle_since, .. } = conn;
    // The short read timeout is the worker's poll point: it observes
    // shutdown and the per-connection idle budget without dedicating a
    // thread to a silent socket forever.  The write timeout keeps a
    // client that stops *reading* from pinning the worker in write_all
    // once the kernel send buffer fills.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let _ = stream.set_write_timeout(Some(shared.cfg.request_timeout));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return None,
    };
    let mut reader = BufReader::new(stream);
    let mut served = 0usize;
    while served < shared.cfg.keep_alive_max {
        let req = match read_request(
            &mut reader,
            &mut writer,
            shared.cfg.max_body,
            shared.cfg.request_timeout,
        ) {
            Ok(None) => return None, // peer closed between requests
            Ok(Some(req)) => req,
            Err(e) => {
                if e.downcast_ref::<IdleTimeout>().is_some() {
                    // Idle at a request boundary (nothing consumed):
                    // safe to keep waiting — until shutdown or the
                    // idle budget runs out.
                    if shared.stop.load(Ordering::Acquire)
                        || idle_since.elapsed() >= shared.cfg.idle_timeout
                    {
                        return None;
                    }
                    let waiting = !shared
                        .queue
                        .lock()
                        .expect("net queue poisoned")
                        .is_empty();
                    if waiting {
                        // Nothing buffered at a boundary: safe to hand
                        // the raw socket back and serve someone else.
                        // Fresh `enqueued` stamp — a silent client must
                        // not perpetually outrank newer arrivals.
                        return Some(Conn {
                            stream: reader.into_inner(),
                            idle_since,
                            enqueued: Instant::now(),
                        });
                    }
                    continue;
                }
                // Anything else — malformed bytes, oversize, or a
                // timeout mid-request — poisons the stream position;
                // answer (best effort) and close rather than resume
                // parsing at a desynchronized offset.
                let status = if e.downcast_ref::<PayloadTooLarge>().is_some()
                {
                    413
                } else if e.downcast_ref::<RequestTimeout>().is_some() {
                    408
                } else {
                    400
                };
                let _ = Response::error(status, &format!("{e:#}"))
                    .write_to(&mut writer, false);
                return None;
            }
        };
        // Close after the in-flight response on shutdown so a busy
        // client cannot stall `Server::shutdown` for keep_alive_max
        // requests.
        let keep = req.keep_alive()
            && served + 1 < shared.cfg.keep_alive_max
            && !shared.stop.load(Ordering::Acquire);
        let resp = dispatch(&shared.router, &req);
        if resp.write_to(&mut writer, keep).is_err() {
            return None;
        }
        served += 1;
        idle_since = Instant::now();
        if !keep {
            return None;
        }
    }
    None
}

/// Route one request to its handler, recording the request into the
/// telemetry layer (HTTP counter + latency histogram + a flight
/// recorder span) on the way out.
pub fn dispatch(router: &Router, req: &Request) -> Response {
    let t0 = Instant::now();
    let resp = route_request(router, req);
    let dur = t0.elapsed();
    let m = http_metrics();
    m.requests.inc();
    m.latency.record(dur.as_nanos().min(u64::MAX as u128) as u64);
    crate::obs::recorder().record(
        "http.request",
        format!("{} {} -> {}", req.method, req.path, resp.status),
        dur,
    );
    resp
}

/// Registry handles for the HTTP-wide metrics family.
struct HttpMetrics {
    requests: Arc<crate::obs::Counter>,
    latency: Arc<crate::obs::Histogram>,
}

fn http_metrics() -> &'static HttpMetrics {
    static METRICS: std::sync::OnceLock<HttpMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(|| {
        let reg = crate::obs::registry();
        HttpMetrics {
            requests: reg.counter(
                "passcode_http_requests_total",
                "HTTP requests dispatched (all endpoints)",
            ),
            latency: reg.histogram(
                "passcode_http_request_seconds",
                "End-to-end request dispatch latency",
                1e-9,
            ),
        }
    })
}

/// The method/path match behind [`dispatch`].
fn route_request(router: &Router, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => handle_healthz(router),
        ("GET", "/v1/stats") => Response::json(200, &router.stats_json()),
        ("GET", "/metrics") => handle_metrics(router),
        ("GET", "/v1/trace") => {
            Response::json(200, &crate::obs::recorder().to_json())
        }
        ("POST", "/v1/score") => handle_score(router, req),
        ("GET", "/v1/dist/pull_w") => handle_dist_pull(router, req),
        ("GET", "/v1/dist/stats") => handle_dist_stats(router),
        ("POST", "/v1/dist/push_delta") => handle_dist_push(router, req),
        ("POST", "/v1/dist/heartbeat") => handle_dist_heartbeat(router, req),
        (method, path) => {
            if let Some(route) = path
                .strip_prefix("/v1/models/")
                .and_then(|rest| rest.strip_suffix("/publish"))
            {
                if method != "POST" {
                    return Response::error(405, "publish requires POST");
                }
                return handle_publish(router, route, req);
            }
            if matches!(
                path,
                "/healthz"
                    | "/v1/stats"
                    | "/metrics"
                    | "/v1/trace"
                    | "/v1/dist/pull_w"
                    | "/v1/dist/stats"
            ) {
                return Response::error(405, "method not allowed");
            }
            if path == "/v1/score" {
                return Response::error(405, "score requires POST");
            }
            if path == "/v1/dist/push_delta" {
                return Response::error(405, "push_delta requires POST");
            }
            if path == "/v1/dist/heartbeat" {
                return Response::error(405, "heartbeat requires POST");
            }
            Response::error(404, &format!("no handler for {method} {path}"))
        }
    }
}

/// Resolve the attached dist coordinator, or explain its absence.
fn dist_coordinator(
    router: &Router,
) -> Result<&Arc<crate::dist::DistCoordinator>, Response> {
    router
        .dist()
        .ok_or_else(|| Response::error(404, "no dist coordinator on this server"))
}

/// `GET /v1/dist/pull_w`: the merged `w` + its merge epoch, binary
/// little-endian (see `dist::protocol`).  An optional `?worker=ID`
/// identifies the puller so the pull doubles as a lease refresh.
fn handle_dist_pull(router: &Router, req: &Request) -> Response {
    let coord = match dist_coordinator(router) {
        Ok(c) => c,
        Err(resp) => return resp,
    };
    if let Some(id) = req.query("worker").and_then(|v| v.parse::<u64>().ok()) {
        coord.touch(id);
    }
    let (epoch, w) = coord.pull();
    Response {
        status: 200,
        content_type: "application/octet-stream",
        body: crate::dist::protocol::encode_w(epoch, &w),
    }
}

/// `GET /v1/dist/stats`: coordinator merge statistics as JSON.
fn handle_dist_stats(router: &Router) -> Response {
    match dist_coordinator(router) {
        Ok(coord) => Response::json(200, &coord.stats_json()),
        Err(resp) => resp,
    }
}

/// `POST /v1/dist/push_delta`: decode the binary delta, run the
/// bounded-staleness merge, answer with the JSON verdict.  Malformed
/// bodies (bad magic, wrong dimension, non-finite values) are 400s;
/// a *stale* delta is a well-formed 200 resync verdict.
fn handle_dist_push(router: &Router, req: &Request) -> Response {
    let coord = match dist_coordinator(router) {
        Ok(c) => c,
        Err(resp) => return resp,
    };
    let delta = match crate::dist::protocol::decode_push(&req.body) {
        Ok(d) => d,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    match coord.push(&delta) {
        Ok(outcome) => Response::json(200, &outcome.to_json()),
        Err(e) => Response::error(400, &format!("{e:#}")),
    }
}

/// `POST /v1/dist/heartbeat`: decode the binary heartbeat, refresh (or
/// refuse) the worker's lease, answer with the JSON lease reply —
/// current epoch plus the worker's assigned shard ranges, or a
/// revocation if the lease already expired.
fn handle_dist_heartbeat(router: &Router, req: &Request) -> Response {
    let coord = match dist_coordinator(router) {
        Ok(c) => c,
        Err(resp) => return resp,
    };
    let hb = match crate::dist::protocol::decode_heartbeat(&req.body) {
        Ok(h) => h,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    Response::json(200, &coord.heartbeat(&hb).to_json())
}

/// `GET /metrics`: sync the scrape-time families (per-route serving
/// metrics, hot probe counters) into the registry, then render the
/// whole thing as Prometheus text.
fn handle_metrics(router: &Router) -> Response {
    let reg = crate::obs::registry();
    router.publish_metrics(reg);
    crate::obs::probes::sync_hot_counters();
    Response::text(200, reg.render())
}

fn handle_healthz(router: &Router) -> Response {
    Response::json(
        200,
        &Json::obj(vec![
            ("status", Json::str("ok")),
            (
                "routes",
                Json::Arr(router.names().into_iter().map(Json::str).collect()),
            ),
        ]),
    )
}

/// Resolve the route a score request targets: `?route=` wins, then the
/// JSON body's `"route"`, then the sole configured route.
fn resolve_route<'r>(
    router: &'r Router,
    req: &Request,
    body_route: Option<&str>,
) -> Result<&'r Route, Response> {
    let name = req.query("route").or(body_route);
    match name {
        Some(name) => router.route(name).ok_or_else(|| {
            Response::error(404, &format!("unknown route {name:?}"))
        }),
        None => router.sole_route().ok_or_else(|| {
            Response::error(
                400,
                &format!(
                    "multiple routes configured; pick one with ?route= (have: {})",
                    router.names().join(", ")
                ),
            )
        }),
    }
}

fn handle_score(router: &Router, req: &Request) -> Response {
    let body = match decode_score_body(req.header("content-type"), &req.body) {
        Ok(b) => b,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let route = match resolve_route(router, req, body.route.as_deref()) {
        Ok(r) => r,
        Err(resp) => return resp,
    };
    // Score before feeding the online trainer, as `replay` does: the
    // reported accuracy must come from the model that served the
    // request, not one the background trainer already fit on these
    // very rows.  The labeled (LIBSVM) path pays a per-row clone for
    // that; the label-less JSON hot path moves rows straight into the
    // queue.
    let labels = body.labels;
    let (preds, ingested) = match &labels {
        Some(l) => {
            let preds = route.score(&body.rows);
            (preds, route.ingest(&body.rows, l))
        }
        None => (route.score_owned(body.rows), 0),
    };
    let mut extra = Vec::new();
    if let Some(labels) = &labels {
        let correct = preds
            .iter()
            .zip(labels)
            .filter(|(p, &y)| p.label == if y > 0.0 { 1.0 } else { -1.0 })
            .count();
        extra.push((
            "accuracy",
            Json::num(correct as f64 / preds.len().max(1) as f64),
        ));
        extra.push(("ingested", Json::num(ingested as f64)));
    }
    let predictions = Json::Arr(
        preds
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("margin", Json::num(p.margin)),
                    ("label", Json::num(p.label)),
                    ("model_epoch", Json::num(p.model_epoch as f64)),
                ])
            })
            .collect(),
    );
    let mut fields = vec![
        ("route", Json::str(&route.name)),
        ("predictions", predictions),
    ];
    fields.extend(extra);
    Response::json(200, &Json::obj(fields))
}

fn handle_publish(router: &Router, route_name: &str, req: &Request) -> Response {
    let route = match router.route(route_name) {
        Some(r) => r,
        None => return Response::error(404, &format!("unknown route {route_name:?}")),
    };
    let path = std::str::from_utf8(&req.body)
        .ok()
        .and_then(|text| Json::parse(text).ok())
        .and_then(|v| v.get("path").ok().cloned())
        .and_then(|p| p.as_str().ok().map(str::to_string));
    let path = match path {
        Some(p) => p,
        None => {
            return Response::error(400, "body must be {\"path\": \"model.json\"}")
        }
    };
    match route.publish_from_file(&path) {
        Ok(epoch) => Response::json(
            200,
            &Json::obj(vec![
                ("route", Json::str(&route.name)),
                ("epoch", Json::num(epoch as f64)),
            ]),
        ),
        Err(e) => Response::error(400, &format!("{e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model_io::Model;
    use crate::serve::{ServeConfig, ServeEngine};

    fn single_router(tag: f64, d: usize) -> Router {
        let model = Model {
            w: vec![tag; d],
            loss: "hinge".into(),
            c: 1.0,
            solver: "test".into(),
            dataset: "toy".into(),
        };
        Router::single(
            "only",
            ServeEngine::start(
                model,
                None,
                &ServeConfig { shards: 1, ..Default::default() },
            ),
        )
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        let (path, query) = match path.split_once('?') {
            None => (path.to_string(), Vec::new()),
            Some((p, q)) => (
                p.to_string(),
                q.split('&')
                    .map(|kv| {
                        let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
                        (k.to_string(), v.to_string())
                    })
                    .collect(),
            ),
        };
        Request {
            method: method.into(),
            path,
            query,
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
            http10: false,
        }
    }

    fn body_json(resp: &Response) -> Json {
        Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
    }

    #[test]
    fn dispatch_health_stats_and_errors() {
        let router = single_router(1.0, 4);
        let h = dispatch(&router, &req("GET", "/healthz", ""));
        assert_eq!(h.status, 200);
        assert_eq!(
            body_json(&h).get("status").unwrap().as_str().unwrap(),
            "ok"
        );
        let s = dispatch(&router, &req("GET", "/v1/stats", ""));
        assert_eq!(s.status, 200);
        assert!(body_json(&s).get("routes").unwrap().opt("only").is_some());

        assert_eq!(dispatch(&router, &req("GET", "/nope", "")).status, 404);
        assert_eq!(dispatch(&router, &req("POST", "/healthz", "")).status, 405);
        assert_eq!(dispatch(&router, &req("GET", "/v1/score", "")).status, 405);
        assert_eq!(dispatch(&router, &req("POST", "/metrics", "")).status, 405);
        assert_eq!(dispatch(&router, &req("POST", "/v1/trace", "")).status, 405);
        assert_eq!(
            dispatch(&router, &req("GET", "/v1/models/only/publish", "")).status,
            405
        );
        router.shutdown();
    }

    #[test]
    fn dispatch_dist_routes() {
        use crate::dist::protocol::{self, Heartbeat, HeartbeatReply, PushDelta, PushOutcome};
        use crate::dist::{DistCoordinator, MergeConfig};

        // Without a coordinator attached the plane 404s (and the GET
        // paths 405 on wrong methods like the other admin endpoints).
        let none = single_router(1.0, 4);
        assert_eq!(dispatch(&none, &req("GET", "/v1/dist/pull_w", "")).status, 404);
        assert_eq!(dispatch(&none, &req("POST", "/v1/dist/pull_w", "")).status, 405);
        assert_eq!(dispatch(&none, &req("GET", "/v1/dist/push_delta", "")).status, 405);
        assert_eq!(dispatch(&none, &req("GET", "/v1/dist/heartbeat", "")).status, 405);
        assert_eq!(dispatch(&none, &req("POST", "/v1/dist/heartbeat", "")).status, 404);
        none.shutdown();

        let coord = Arc::new(DistCoordinator::new(
            vec![0.0; 2],
            MergeConfig { workers: 2, max_lag: 4, ..Default::default() },
        ));
        let router = Router::empty().with_dist(coord);
        // A pull with ?worker= is still a plain pull when leases are
        // off (the refresh is a no-op, never an error).
        let pull = dispatch(&router, &req("GET", "/v1/dist/pull_w?worker=0", ""));
        assert_eq!(pull.status, 200);
        assert_eq!(protocol::decode_w(&pull.body).unwrap(), (0, vec![0.0, 0.0]));

        let mut push = req("POST", "/v1/dist/push_delta", "");
        push.body = protocol::encode_push(&PushDelta {
            worker: 0,
            boot: 0,
            round: 0,
            base_epoch: 0,
            delta_err: 0.0,
            delta: vec![1.0, -1.0],
        });
        let resp = dispatch(&router, &push);
        assert_eq!(resp.status, 200);
        match PushOutcome::from_json(&body_json(&resp)).unwrap() {
            PushOutcome::Accepted { epoch, weight } => {
                assert_eq!(epoch, 1);
                assert_eq!(weight, 1.0);
            }
            other => panic!("unexpected {other:?}"),
        }

        // Heartbeat round-trips the lease reply (leases off: announced
        // ranges are echoed back, never revoked).
        let mut hb = req("POST", "/v1/dist/heartbeat", "");
        hb.body = protocol::encode_heartbeat(&Heartbeat { worker: 0, ranges: vec![(0, 10)] });
        let hresp = dispatch(&router, &hb);
        assert_eq!(hresp.status, 200);
        let reply = HeartbeatReply::from_json(&body_json(&hresp)).unwrap();
        assert!(!reply.revoked);
        assert_eq!(reply.shards, vec![(0, 10)]);

        let stats = dispatch(&router, &req("GET", "/v1/dist/stats", ""));
        assert_eq!(stats.status, 200);
        assert_eq!(body_json(&stats).get("merge_epoch").unwrap().as_usize().unwrap(), 1);
        // Garbage bodies: 400, not a panic.
        let mut bad = req("POST", "/v1/dist/push_delta", "");
        bad.body = b"XXXX".to_vec();
        assert_eq!(dispatch(&router, &bad).status, 400);
        let mut badhb = req("POST", "/v1/dist/heartbeat", "");
        badhb.body = b"XXXX".to_vec();
        assert_eq!(dispatch(&router, &badhb).status, 400);
        router.shutdown();
    }

    #[test]
    fn dispatch_score_single_batch_and_libsvm() {
        let router = single_router(2.0, 4);
        // Sole route: no selector needed.
        let r = dispatch(
            &router,
            &req("POST", "/v1/score", r#"{"idx": [0, 2], "vals": [1.0, 1.0]}"#),
        );
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        let preds = j.get("predictions").unwrap().as_arr().unwrap();
        assert_eq!(preds.len(), 1);
        assert_eq!(preds[0].get("margin").unwrap().as_f64().unwrap(), 4.0);

        let r = dispatch(
            &router,
            &req(
                "POST",
                "/v1/score?route=only",
                r#"{"rows": [{"idx": [0], "vals": [1.0]}, {"idx": [1], "vals": [-1.0]}]}"#,
            ),
        );
        let preds_j = body_json(&r);
        let preds = preds_j.get("predictions").unwrap().as_arr().unwrap();
        assert_eq!(preds.len(), 2);
        assert_eq!(preds[1].get("label").unwrap().as_f64().unwrap(), -1.0);

        // LIBSVM body: labels come back as accuracy (w = 2·1 ⇒ margins
        // positive whenever the row sum is positive).
        let r = dispatch(&router, &req("POST", "/v1/score", "+1 1:1.0\n-1 2:1.0\n"));
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        assert_eq!(j.get("accuracy").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(j.get("ingested").unwrap().as_usize().unwrap(), 0);

        // Unknown routes and malformed bodies are 4xx.
        assert_eq!(
            dispatch(
                &router,
                &req(
                    "POST",
                    "/v1/score?route=ghost",
                    r#"{"idx": [0], "vals": [1.0]}"#
                )
            )
            .status,
            404
        );
        assert_eq!(
            dispatch(&router, &req("POST", "/v1/score", "not json {")).status,
            400
        );
        router.shutdown();
    }

    #[test]
    fn dispatch_publish_round_trip() {
        let dir = std::env::temp_dir().join("passcode_net_server");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pub.json");
        Model {
            w: vec![9.0; 4],
            loss: "hinge".into(),
            c: 1.0,
            solver: "test".into(),
            dataset: "toy".into(),
        }
        .save(&path)
        .unwrap();

        let router = single_router(1.0, 4);
        let body = format!("{{\"path\": {:?}}}", path.to_str().unwrap());
        let r = dispatch(&router, &req("POST", "/v1/models/only/publish", &body));
        assert_eq!(r.status, 200, "{:?}", String::from_utf8_lossy(&r.body));
        assert_eq!(body_json(&r).get("epoch").unwrap().as_usize().unwrap(), 1);
        let score = dispatch(
            &router,
            &req("POST", "/v1/score", r#"{"idx": [0], "vals": [1.0]}"#),
        );
        let score_j = body_json(&score);
        let p = &score_j.get("predictions").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("margin").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(p.get("model_epoch").unwrap().as_usize().unwrap(), 1);

        assert_eq!(
            dispatch(&router, &req("POST", "/v1/models/ghost/publish", &body)).status,
            404
        );
        assert_eq!(
            dispatch(&router, &req("POST", "/v1/models/only/publish", "{}")).status,
            400
        );
        assert_eq!(
            dispatch(
                &router,
                &req("POST", "/v1/models/only/publish", "{\"path\": \"/no/such\"}")
            )
            .status,
            400
        );
        router.shutdown();
    }

    #[test]
    fn metrics_scrape_covers_http_and_route_families() {
        let router = single_router(2.0, 4);
        let before = dispatch(&router, &req("GET", "/metrics", ""));
        assert_eq!(before.status, 200);
        assert!(before.content_type.starts_with("text/plain"));
        for _ in 0..3 {
            let r = dispatch(
                &router,
                &req("POST", "/v1/score", r#"{"idx": [0], "vals": [1.0]}"#),
            );
            assert_eq!(r.status, 200);
        }
        let after = dispatch(&router, &req("GET", "/metrics", ""));
        let text = String::from_utf8(after.body).unwrap();
        assert!(text.contains("# TYPE passcode_http_requests_total counter"), "{text}");
        assert!(text.contains("# TYPE passcode_http_request_seconds summary"), "{text}");
        assert!(text.contains("passcode_route_requests_total{route=\"only\"} 3"), "{text}");
        assert!(text.contains("passcode_route_qps{route=\"only\"}"), "{text}");
        assert!(
            text.contains("passcode_route_latency_seconds{route=\"only\",quantile=\"0.99\"}"),
            "{text}"
        );
        assert!(text.contains("passcode_route_versions_alive{route=\"only\"}"), "{text}");
        router.shutdown();
    }

    #[test]
    fn trace_endpoint_returns_recent_spans() {
        let router = single_router(1.0, 4);
        dispatch(&router, &req("GET", "/healthz", ""));
        let r = dispatch(&router, &req("GET", "/v1/trace", ""));
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "passcode-trace-v1");
        let events = j.get("events").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        // The healthz dispatch above is in the ring (possibly among
        // events from concurrently running tests — the recorder is
        // process-global).
        let labels: Vec<&str> = events
            .iter()
            .map(|e| e.get("label").unwrap().as_str().unwrap())
            .collect();
        assert!(
            labels.iter().any(|l| l.contains("GET /healthz -> 200")),
            "{labels:?}"
        );
        router.shutdown();
    }
}
