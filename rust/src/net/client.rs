//! Self-contained HTTP/1.1 client and load generator for the `net`
//! front end — what `benches/net_throughput.rs` and the integration
//! tests drive traffic with (no curl in the offline image).
//!
//! [`HttpClient`] keeps one keep-alive connection; [`run_load`] spawns
//! a fleet of them and reports end-to-end QPS + latency percentiles
//! through the same [`LatencyHistogram`] the server side uses.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::serve::LatencyHistogram;
use crate::util::Json;

use super::body::SparseRow;

/// Marker error: the request failed in a way consistent with a stale
/// keep-alive connection — the send itself failed, or the peer closed
/// before a single response byte.  Retrying on a *reused* connection
/// is then almost certainly safe (the typical cause is the server
/// idle-closing the socket before this request arrived); any failure
/// after response bytes started flowing is never retried.
#[derive(Debug)]
struct StaleConn;

impl std::fmt::Display for StaleConn {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("connection failed before any response byte")
    }
}

impl std::error::Error for StaleConn {}

/// A decoded client-side response.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    /// HTTP status code.
    pub status: u16,
    /// Raw body bytes.
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json> {
        Json::parse(std::str::from_utf8(&self.body).context("non-UTF-8 body")?)
    }

    /// Bail unless the status is 2xx (error message carries the body).
    pub fn ok(self) -> Result<ClientResponse> {
        ensure!(
            (200..300).contains(&self.status),
            "HTTP {}: {}",
            self.status,
            String::from_utf8_lossy(&self.body)
        );
        Ok(self)
    }
}

/// Client-side socket policy: connect/read deadlines plus the bounded
/// retry-with-backoff budget [`HttpClient::get_with_retry`] spends.
///
/// The defaults match the client's historical behavior (generous
/// deadlines, 3 retries with doubling backoff); the `dist/` worker
/// loop tightens them so a dead coordinator surfaces as an error in
/// seconds rather than hanging the worker indefinitely.
#[derive(Debug, Clone, Copy)]
pub struct ClientConfig {
    /// Deadline for establishing the TCP connection.
    pub connect_timeout: Duration,
    /// Per-read socket deadline once connected.
    pub read_timeout: Duration,
    /// Extra attempts `get_with_retry` may spend after the first
    /// (0 disables retrying entirely).
    pub retries: u32,
    /// Sleep before the first retry; doubles on each subsequent one.
    pub backoff: Duration,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(10),
            read_timeout: Duration::from_secs(60),
            retries: 3,
            backoff: Duration::from_millis(100),
        }
    }
}

/// One keep-alive HTTP/1.1 connection to the server.
#[derive(Debug)]
pub struct HttpClient {
    addr: SocketAddr,
    cfg: ClientConfig,
    conn: Option<BufReader<TcpStream>>,
}

impl HttpClient {
    /// Connect to `addr` (lazily — the socket opens on first request)
    /// with the default [`ClientConfig`].
    pub fn new(addr: SocketAddr) -> HttpClient {
        Self::with_config(addr, ClientConfig::default())
    }

    /// Connect to `addr` with explicit timeout/retry policy.
    pub fn with_config(addr: SocketAddr, cfg: ClientConfig) -> HttpClient {
        HttpClient { addr, cfg, conn: None }
    }

    fn connect(&mut self) -> Result<&mut BufReader<TcpStream>> {
        if self.conn.is_none() {
            let stream = TcpStream::connect_timeout(&self.addr, self.cfg.connect_timeout)
                .with_context(|| format!("connect {}", self.addr))?;
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(Some(self.cfg.read_timeout)).ok();
            self.conn = Some(BufReader::new(stream));
        }
        Ok(self.conn.as_mut().expect("just connected"))
    }

    /// Issue one request on the keep-alive connection.
    ///
    /// Retries exactly once, and only when a *reused* connection
    /// failed before any response byte arrived (see [`StaleConn`]) —
    /// the overwhelmingly likely cause is the server idle-closing the
    /// socket between our requests, before it ever saw this one.
    /// Failures on fresh connections, or after response bytes started
    /// flowing, propagate: retrying those risks duplicating a
    /// non-idempotent POST the server may already have processed.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<ClientResponse> {
        let reused = self.conn.is_some();
        match self.request_once(method, path, content_type, body) {
            Err(e) if reused && e.downcast_ref::<StaleConn>().is_some() => {
                self.conn = None;
                self.request_once(method, path, content_type, body)
            }
            other => other,
        }
    }

    fn request_once(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<ClientResponse> {
        let conn = self.connect()?;
        // One write_all for the whole request: per-fragment writes on a
        // TCP_NODELAY socket would emit a packet per fragment.
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: passcode\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        let mut wire = Vec::with_capacity(head.len() + body.len());
        wire.extend_from_slice(head.as_bytes());
        wire.extend_from_slice(body);
        let sent: std::io::Result<()> = {
            let stream = conn.get_mut();
            stream.write_all(&wire).and_then(|()| stream.flush())
        };
        if let Err(e) = sent {
            self.conn = None;
            return Err(anyhow::Error::new(StaleConn)
                .context(format!("send {method} {path}: {e}")));
        }
        let resp = read_response(conn);
        if resp.is_err() {
            self.conn = None;
        }
        resp
    }

    /// `GET path` convenience.
    pub fn get(&mut self, path: &str) -> Result<ClientResponse> {
        self.request("GET", path, "text/plain", b"")
    }

    /// `GET path` with up to `cfg.retries` extra attempts on transport
    /// errors, sleeping `cfg.backoff` (doubling each time) between
    /// attempts and reconnecting from scratch before each retry.
    ///
    /// Only for GETs: they are idempotent, so re-sending after an
    /// ambiguous failure is safe.  Non-2xx responses are *not* retried
    /// — the server answered; retrying would just repeat the answer.
    pub fn get_with_retry(&mut self, path: &str) -> Result<ClientResponse> {
        let mut backoff = self.cfg.backoff;
        let mut last_err = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
                self.conn = None;
            }
            match self.get(path) {
                Ok(resp) => return Ok(resp),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one attempt ran").context(format!(
            "GET {path} failed after {} attempts",
            self.cfg.retries + 1
        )))
    }

    /// `POST path` with the same bounded retry-with-backoff budget as
    /// [`Self::get_with_retry`]: up to `cfg.retries` extra attempts on
    /// transport errors, doubling backoff, fresh connection per retry.
    ///
    /// POSTs are not idempotent in general — an ambiguous failure may
    /// mean the server already processed the body — so this is only
    /// for endpoints whose bodies carry an application-level
    /// idempotence key.  The dist push protocol qualifies: every
    /// `push_delta` body carries a `(worker, boot, round)` id and the
    /// coordinator merges each id exactly once, so re-sending after a
    /// timeout at worst re-fetches the recorded verdict.  Non-2xx
    /// responses are *not* retried — the server answered.
    pub fn post_with_retry(
        &mut self,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<ClientResponse> {
        let mut backoff = self.cfg.backoff;
        let mut last_err = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
                self.conn = None;
            }
            match self.request("POST", path, content_type, body) {
                Ok(resp) => return Ok(resp),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one attempt ran").context(format!(
            "POST {path} failed after {} attempts",
            self.cfg.retries + 1
        )))
    }

    /// `POST /v1/score` of one sparse row against `route`.
    pub fn score(&mut self, route: &str, row: &SparseRow) -> Result<ClientResponse> {
        self.request(
            "POST",
            &format!("/v1/score?route={route}"),
            "application/json",
            score_row_json(row).as_bytes(),
        )
    }
}

/// Serialize one row as a single-row score body.
pub fn score_row_json((idx, vals): &SparseRow) -> String {
    Json::obj(vec![
        (
            "idx",
            Json::Arr(idx.iter().map(|&j| Json::num(j as f64)).collect()),
        ),
        ("vals", Json::arr_f64(vals)),
    ])
    .to_string()
}

fn read_response<R: BufRead>(r: &mut R) -> Result<ClientResponse> {
    let mut status_line = String::new();
    match r.read_line(&mut status_line) {
        Ok(0) => {
            return Err(anyhow::Error::new(StaleConn)
                .context("connection closed before status line"))
        }
        Ok(_) => {}
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::UnexpectedEof
            ) =>
        {
            return Err(anyhow::Error::new(StaleConn)
                .context(format!("read status line: {e}")))
        }
        Err(e) => return Err(e.into()),
    }
    let mut parts = status_line.split_ascii_whitespace();
    let version = parts.next().context("empty status line")?;
    ensure!(version.starts_with("HTTP/1."), "not HTTP: {status_line:?}");
    let status: u16 = parts
        .next()
        .context("status line missing code")?
        .parse()
        .context("bad status code")?;
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        ensure!(r.read_line(&mut line)? > 0, "connection closed in headers");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad Content-Length")?;
            }
        } else {
            bail!("malformed response header {line:?}");
        }
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).context("connection closed mid-body")?;
    Ok(ClientResponse { status, body })
}

/// Load-generator shape.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Concurrent client connections.
    pub connections: usize,
    /// Requests each connection issues.
    pub requests_per_conn: usize,
}

impl Default for LoadConfig {
    fn default() -> Self {
        Self { connections: 4, requests_per_conn: 250 }
    }
}

/// What a load run measured (client-side, end to end over loopback).
#[derive(Debug, Clone, Copy)]
pub struct LoadReport {
    /// Requests that completed with HTTP 200.
    pub requests: u64,
    /// Requests that failed (transport error or non-200).
    pub errors: u64,
    /// Wall-clock seconds for the whole run.
    pub elapsed_secs: f64,
    /// Completed requests per second.
    pub qps: f64,
    /// Median end-to-end latency (seconds).
    pub p50_secs: f64,
    /// 95th-percentile latency (seconds).
    pub p95_secs: f64,
    /// 99th-percentile latency (seconds).
    pub p99_secs: f64,
}

/// Hammer `POST /v1/score` on `route` with `rows` (cycled) from
/// `cfg.connections` concurrent keep-alive connections; client-side
/// QPS and latency percentiles.
pub fn run_load(
    addr: SocketAddr,
    route: &str,
    rows: &[SparseRow],
    cfg: &LoadConfig,
) -> Result<LoadReport> {
    ensure!(!rows.is_empty(), "no rows to send");
    // Pre-serialize the request bodies once; the wire bytes are
    // identical across connections.
    let bodies: Arc<Vec<String>> =
        Arc::new(rows.iter().map(score_row_json).collect());
    let hist = Arc::new(LatencyHistogram::new());
    let errors = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let started = Instant::now();
    std::thread::scope(|s| {
        for t in 0..cfg.connections.max(1) {
            let bodies = Arc::clone(&bodies);
            let hist = Arc::clone(&hist);
            let errors = Arc::clone(&errors);
            let path = format!("/v1/score?route={route}");
            s.spawn(move || {
                let mut client = HttpClient::new(addr);
                for i in 0..cfg.requests_per_conn {
                    let body = &bodies[(t + i) % bodies.len()];
                    let sent = Instant::now();
                    match client.request(
                        "POST",
                        &path,
                        "application/json",
                        body.as_bytes(),
                    ) {
                        Ok(r) if r.status == 200 => hist.record(sent.elapsed()),
                        _ => {
                            errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let elapsed = started.elapsed().as_secs_f64();
    let requests = hist.count();
    Ok(LoadReport {
        requests,
        errors: errors.load(std::sync::atomic::Ordering::Relaxed),
        elapsed_secs: elapsed,
        qps: if elapsed > 0.0 { requests as f64 / elapsed } else { 0.0 },
        p50_secs: hist.quantile_secs(0.50),
        p95_secs: hist.quantile_secs(0.95),
        p99_secs: hist.quantile_secs(0.99),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_row_json_shape() {
        let s = score_row_json(&(vec![0, 7], vec![0.5, -1.0]));
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("idx").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("vals").unwrap().as_arr().unwrap()[1].as_f64().unwrap(),
            -1.0
        );
    }

    #[test]
    fn get_with_retry_bounds_attempts_against_dead_peer() {
        // Nothing listens on this loopback port: each attempt fails at
        // connect.  The retry budget must bound the loop and the error
        // must say how many attempts were spent.
        let cfg = ClientConfig {
            connect_timeout: Duration::from_millis(250),
            read_timeout: Duration::from_millis(250),
            retries: 1,
            backoff: Duration::from_millis(1),
        };
        let mut c = HttpClient::with_config("127.0.0.1:9".parse().unwrap(), cfg);
        let err = c.get_with_retry("/healthz").unwrap_err();
        assert!(err.to_string().contains("after 2 attempts"), "{err:#}");
    }

    #[test]
    fn post_with_retry_bounds_attempts_against_dead_peer() {
        let cfg = ClientConfig {
            connect_timeout: Duration::from_millis(250),
            read_timeout: Duration::from_millis(250),
            retries: 1,
            backoff: Duration::from_millis(1),
        };
        let mut c = HttpClient::with_config("127.0.0.1:9".parse().unwrap(), cfg);
        let err = c
            .post_with_retry("/v1/dist/push_delta", "application/octet-stream", b"x")
            .unwrap_err();
        assert!(err.to_string().contains("after 2 attempts"), "{err:#}");
    }

    #[test]
    fn read_response_parses_and_rejects() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Type: x\r\nContent-Length: 2\r\n\r\nhi";
        let r = read_response(&mut BufReader::new(&raw[..])).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body, b"hi");
        assert!(r.clone().ok().is_ok());
        let err = ClientResponse { status: 500, body: b"boom".to_vec() };
        assert!(err.ok().is_err());

        assert!(read_response(&mut BufReader::new(&b""[..])).is_err());
        assert!(read_response(&mut BufReader::new(&b"garbage\r\n\r\n"[..])).is_err());
    }
}
