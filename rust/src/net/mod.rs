//! Network front end: a std-only HTTP/1.1 server with multi-model
//! routing over [`serve::ServeEngine`](crate::serve::ServeEngine).
//!
//! PR 2 made the PASSCoDe solver an in-process scoring engine; this
//! subsystem puts a real socket in front of it so traffic can enter
//! over the network — the ROADMAP's "heavy traffic from millions of
//! users" north star needs a listener, not a replay harness.  No new
//! dependencies: the protocol layer is hand-rolled on
//! `std::net::TcpListener`, matching the repo's no-serde/no-hyper
//! discipline.
//!
//! * [`http`] — minimal HTTP/1.1 request parsing / response writing
//!   (keep-alive, `Content-Length` bodies, bounded sizes).
//! * [`body`] — `POST /v1/score` body decoding: JSON (single or batch
//!   sparse rows) and LIBSVM text.
//! * [`router`] — route/tenant names → independent
//!   [`ServeEngine`](crate::serve::ServeEngine)s (each with its own
//!   registry and optional online trainer), built from a multi-model
//!   JSON config.
//! * [`server`] — the accept loop + bounded worker pool, request
//!   dispatch, and the admin plane (`/v1/models/{route}/publish`,
//!   `/v1/stats`, `/healthz`, plus the telemetry plane `/metrics` and
//!   `/v1/trace` backed by [`crate::obs`], plus the distributed merge
//!   plane `/v1/dist/push_delta` / `/v1/dist/pull_w` / `/v1/dist/stats`
//!   when a [`crate::dist::DistCoordinator`] is attached).
//! * [`client`] — keep-alive HTTP client + load generator
//!   (`benches/net_throughput.rs`), with configurable connect/read
//!   timeouts and bounded retry-with-backoff for idempotent GETs
//!   ([`ClientConfig`]).
//!
//! Serving many independently trained models side by side mirrors the
//! multi-worker decomposition in Hybrid-DCA (Pal et al., 2016); each
//! route's optional online trainer keeps running the racy
//! PASSCoDe-Wild updates whose backward error Theorem 3 bounds, and a
//! publish on one route can never perturb another (isolated
//! registries, queues, and shard pools).
//!
//! ```no_run
//! use passcode::net::{Router, RoutesConfig, Server, ServerConfig};
//!
//! let routes = RoutesConfig::from_file("routes.json").unwrap();
//! let server = Server::start(
//!     Router::start(&routes).unwrap(),
//!     &ServerConfig { addr: "127.0.0.1:8080".into(), ..Default::default() },
//! )
//! .unwrap();
//! println!("listening on {}", server.addr());
//! // ... later:
//! for (route, report) in server.shutdown() {
//!     println!("{route}: {}", report.render());
//! }
//! ```

pub mod body;
pub mod client;
pub mod http;
pub mod router;
pub mod server;

pub use body::{decode_score_body, ScoreBody, SparseRow};
pub use client::{
    run_load, ClientConfig, ClientResponse, HttpClient, LoadConfig, LoadReport,
};
pub use http::{
    IdleTimeout, PayloadTooLarge, Request, RequestTimeout, Response,
};
pub use router::{Route, Router, RouteSpec, RoutesConfig};
pub use server::{dispatch, Server, ServerConfig};
