//! Multi-model routing: route/tenant names mapped to independent
//! [`ServeEngine`] instances, each with its own
//! [`ModelRegistry`](crate::serve::ModelRegistry) and (optionally) a
//! continuously-running [`OnlineTrainer`].
//!
//! One route = one isolated serving universe: its own registry epochs,
//! its own microbatch queue, its own scorer shards, its own stats.
//! A hot-swap publish on route A can therefore never perturb route B —
//! the per-route isolation the Hybrid-DCA decomposition suggests for
//! serving many independently trained models side by side.
//!
//! Routes come from a JSON config file ([`RoutesConfig`]):
//!
//! ```json
//! {"routes": [
//!   {"name": "a", "model": "a-model.json", "shards": 2},
//!   {"name": "b", "dataset": "rcv1", "scale": 0.05, "epochs": 10,
//!    "online": true, "online_min_rows": 256}
//! ]}
//! ```
//!
//! A route serves either a saved model file (`"model"`) or a model
//! trained at startup from a registry dataset (`"dataset"`).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::config::RunConfig;
use crate::coordinator::driver;
use crate::coordinator::model_io::Model;
use crate::loss::LossKind;
use crate::serve::{
    OnlineConfig, OnlineTrainer, Prediction, ServeConfig, ServeEngine,
    ThroughputReport,
};
use crate::util::Json;

use super::body::SparseRow;

/// Configuration of one route (see module docs for the JSON shape).
#[derive(Debug, Clone)]
pub struct RouteSpec {
    /// Route name (the `route` selector in requests); `[A-Za-z0-9_-]+`.
    pub name: String,
    /// Path to a saved model JSON (mutually exclusive with `dataset`).
    pub model: Option<String>,
    /// Registry dataset to train a fresh model from at startup.
    pub dataset: Option<String>,
    /// Dataset scale factor for startup training.
    pub scale: f64,
    /// Epochs for startup training.
    pub epochs: usize,
    /// Solver threads for startup/online training.
    pub threads: usize,
    /// Scoring engine shape for this route.
    pub serve: ServeConfig,
    /// Attach a continuous online trainer (requires hinge loss).
    pub online: bool,
    /// Wild epochs per online round.
    pub online_epochs: usize,
    /// Sliding-window capacity of the online trainer.
    pub online_window: usize,
    /// Buffered rows before the background loop runs a round.
    pub online_min_rows: usize,
    /// RNG seed for training on this route.
    pub seed: u64,
}

impl Default for RouteSpec {
    fn default() -> Self {
        Self {
            name: "default".into(),
            model: None,
            dataset: None,
            scale: 0.05,
            epochs: 10,
            threads: 2,
            serve: ServeConfig::default(),
            online: false,
            online_epochs: 2,
            online_window: 4096,
            online_min_rows: 256,
            seed: 42,
        }
    }
}

/// Keys a route object may carry — anything else is a typo and fails
/// loudly, the same policy `Cli::check_flags` applies to CLI flags.
const ROUTE_KEYS: &[&str] = &[
    "name", "model", "dataset", "scale", "epochs", "threads", "shards",
    "max_batch", "max_wait_us", "pin_threads", "online", "online_epochs",
    "online_window", "online_min_rows", "seed",
];

impl RouteSpec {
    /// Parse one route object from config JSON.
    pub fn from_json(v: &Json) -> Result<RouteSpec> {
        for key in v.as_obj()?.keys() {
            ensure!(
                ROUTE_KEYS.contains(&key.as_str()),
                "unknown key {key:?} in route config (known: {})",
                ROUTE_KEYS.join(", ")
            );
        }
        let mut s = RouteSpec {
            name: v.get("name")?.as_str()?.to_string(),
            ..Default::default()
        };
        ensure!(
            !s.name.is_empty()
                && s.name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
            "route name {:?} must be [A-Za-z0-9_-]+",
            s.name
        );
        if let Some(m) = v.opt("model") {
            s.model = Some(m.as_str()?.to_string());
        }
        if let Some(d) = v.opt("dataset") {
            s.dataset = Some(d.as_str()?.to_string());
        }
        ensure!(
            s.model.is_some() != s.dataset.is_some(),
            "route {:?} needs exactly one of \"model\" or \"dataset\"",
            s.name
        );
        if let Some(x) = v.opt("scale") {
            s.scale = x.as_f64()?;
        }
        if let Some(x) = v.opt("epochs") {
            s.epochs = x.as_usize()?;
        }
        if let Some(x) = v.opt("threads") {
            s.threads = x.as_usize()?.max(1);
        }
        if let Some(x) = v.opt("shards") {
            s.serve.shards = x.as_usize()?.max(1);
        }
        if let Some(x) = v.opt("max_batch") {
            s.serve.max_batch = x.as_usize()?.max(1);
        }
        if let Some(x) = v.opt("max_wait_us") {
            s.serve.max_wait = Duration::from_micros(x.as_usize()? as u64);
        }
        if let Some(x) = v.opt("pin_threads") {
            s.serve.pin_threads = x.as_bool()?;
        }
        if let Some(x) = v.opt("online") {
            s.online = x.as_bool()?;
        }
        if let Some(x) = v.opt("online_epochs") {
            s.online_epochs = x.as_usize()?.max(1);
        }
        if let Some(x) = v.opt("online_window") {
            s.online_window = x.as_usize()?.max(1);
        }
        if let Some(x) = v.opt("online_min_rows") {
            s.online_min_rows = x.as_usize()?.max(1);
        }
        if let Some(x) = v.opt("seed") {
            s.seed = x.as_usize()? as u64;
        }
        ensure!(
            !s.online || s.online_min_rows <= s.online_window,
            "route {:?}: online_min_rows ({}) exceeds online_window ({}) — \
             the window evicts down to {} rows, so the trainer would never \
             reach its trigger",
            s.name,
            s.online_min_rows,
            s.online_window,
            s.online_window
        );
        Ok(s)
    }
}

/// The multi-route config file: `{"routes": [...]}`.
#[derive(Debug, Clone, Default)]
pub struct RoutesConfig {
    /// One spec per route.
    pub routes: Vec<RouteSpec>,
}

impl RoutesConfig {
    /// Parse from config JSON text.
    pub fn from_json_text(text: &str) -> Result<RoutesConfig> {
        let v = Json::parse(text).context("malformed routes config JSON")?;
        let routes = v
            .get("routes")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, r)| RouteSpec::from_json(r).with_context(|| format!("routes[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        ensure!(!routes.is_empty(), "config declares no routes");
        let mut seen = std::collections::BTreeSet::new();
        for r in &routes {
            ensure!(seen.insert(r.name.clone()), "duplicate route {:?}", r.name);
        }
        Ok(RoutesConfig { routes })
    }

    /// Load from a config file on disk.
    pub fn from_file(path: impl AsRef<Path>) -> Result<RoutesConfig> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read routes config {}", path.display()))?;
        Self::from_json_text(&text)
            .with_context(|| format!("routes config {}", path.display()))
    }
}

/// One live route: a serving engine plus its optional online trainer
/// (with the trainer's background round loop).
pub struct Route {
    /// Route name.
    pub name: String,
    engine: ServeEngine,
    trainer: Option<Arc<OnlineTrainer>>,
    trainer_stop: Arc<AtomicBool>,
    trainer_loop: Option<JoinHandle<u64>>,
}

impl Route {
    /// Bring a route up from its spec: load or train the model, start
    /// the engine, and (when `online`) spawn the training loop.
    pub fn start(spec: &RouteSpec) -> Result<Route> {
        let (model, alpha) = match (&spec.model, &spec.dataset) {
            (Some(path), _) => (
                Model::load(path).with_context(|| format!("route {:?}", spec.name))?,
                None,
            ),
            (None, Some(dataset)) => {
                let cfg = RunConfig {
                    dataset: dataset.clone(),
                    scale: spec.scale,
                    epochs: spec.epochs,
                    threads: spec.threads,
                    seed: spec.seed,
                    eval_every: 0,
                    ..Default::default()
                };
                let (model, result) = driver::train_model(&cfg)
                    .with_context(|| format!("train route {:?}", spec.name))?;
                (model, Some(result.alpha))
            }
            (None, None) => bail!("route {:?} has neither model nor dataset", spec.name),
        };
        if spec.online {
            ensure!(
                model.loss == "hinge",
                "route {:?}: online training supports hinge loss, model has {:?}",
                spec.name,
                model.loss
            );
        }
        let c = model.c;
        let engine = ServeEngine::start(model, alpha, &spec.serve);
        let trainer_stop = Arc::new(AtomicBool::new(false));
        let (trainer, trainer_loop) = if spec.online {
            let t = Arc::new(OnlineTrainer::new(
                Arc::clone(engine.registry()),
                LossKind::Hinge,
                c,
                OnlineConfig {
                    epochs_per_round: spec.online_epochs,
                    threads: spec.threads.max(1),
                    max_window: spec.online_window,
                    seed: spec.seed,
                    ..Default::default()
                },
            ));
            let h = OnlineTrainer::spawn_loop(
                Arc::clone(&t),
                Arc::clone(&trainer_stop),
                spec.online_min_rows,
            );
            (Some(t), Some(h))
        } else {
            (None, None)
        };
        Ok(Route { name: spec.name.clone(), engine, trainer, trainer_stop, trainer_loop })
    }

    /// Score a batch of raw sparse rows (submit all, then wait all, so
    /// rows of one request coalesce into shared microbatches).
    pub fn score(&self, rows: &[SparseRow]) -> Vec<Prediction> {
        self.score_owned(rows.to_vec())
    }

    /// [`Route::score`] without the copy: rows move straight into the
    /// microbatch queue (the HTTP handler's hot path — it owns the
    /// decoded body, so cloning per row would be pure overhead).
    pub fn score_owned(&self, rows: Vec<SparseRow>) -> Vec<Prediction> {
        let tickets: Vec<_> = rows
            .into_iter()
            .map(|(idx, vals)| self.engine.submit(idx, vals))
            .collect();
        tickets.into_iter().map(|t| t.wait()).collect()
    }

    /// Feed labeled rows to the route's online trainer.  Returns how
    /// many rows were ingested (0 when the route has no trainer).
    pub fn ingest(&self, rows: &[SparseRow], labels: &[f64]) -> usize {
        match &self.trainer {
            None => 0,
            Some(t) => {
                let n = rows.len().min(labels.len());
                for ((idx, vals), &y) in rows.iter().zip(labels).take(n) {
                    t.ingest(idx.clone(), vals.clone(), y);
                }
                n
            }
        }
    }

    /// Hot-swap a model file into this route's registry; returns the
    /// new epoch.  The new model must match the served dimension —
    /// publishing a mismatched model would silently zero-score live
    /// features.
    pub fn publish_from_file(&self, path: &str) -> Result<u64> {
        let model = Model::load(path)?;
        let current = self.engine.registry().current();
        ensure!(
            model.w.len() == current.model.w.len(),
            "dimension mismatch: route serves d={}, file has d={}",
            current.model.w.len(),
            model.w.len()
        );
        Ok(self.engine.registry().publish(model, None))
    }

    /// The route's serving engine.
    pub fn engine(&self) -> &ServeEngine {
        &self.engine
    }

    /// Live report for this route (includes registry depth).
    pub fn report(&self) -> ThroughputReport {
        self.engine.report()
    }

    fn shutdown(mut self) -> ThroughputReport {
        self.trainer_stop.store(true, Ordering::Release);
        if let Some(h) = self.trainer_loop.take() {
            let _ = h.join();
        }
        self.engine.shutdown()
    }
}

impl std::fmt::Debug for Route {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Route({}, online={})",
            self.name,
            self.trainer.is_some()
        )
    }
}

/// The dispatch table: route name → live [`Route`], plus (optionally)
/// the distributed-tier coordinator the `/v1/dist/*` plane serves.
#[derive(Debug)]
pub struct Router {
    routes: BTreeMap<String, Route>,
    dist: Option<Arc<crate::dist::DistCoordinator>>,
}

impl Router {
    /// Bring up every route in the config.
    pub fn start(cfg: &RoutesConfig) -> Result<Router> {
        ensure!(!cfg.routes.is_empty(), "config declares no routes");
        let mut routes = BTreeMap::new();
        for spec in &cfg.routes {
            ensure!(
                !routes.contains_key(&spec.name),
                "duplicate route {:?}",
                spec.name
            );
            routes.insert(spec.name.clone(), Route::start(spec)?);
        }
        Ok(Router { routes, dist: None })
    }

    /// A single-route router around an already-built engine (the
    /// `passcode listen --model` fast path and tests).
    pub fn single(name: &str, engine: ServeEngine) -> Router {
        let mut routes = BTreeMap::new();
        routes.insert(
            name.to_string(),
            Route {
                name: name.to_string(),
                engine,
                trainer: None,
                trainer_stop: Arc::new(AtomicBool::new(false)),
                trainer_loop: None,
            },
        );
        Router { routes, dist: None }
    }

    /// A router with no scoring routes at all — the shape a pure
    /// `passcode dist-coord` process runs (only the admin plane and
    /// `/v1/dist/*` are live).
    pub fn empty() -> Router {
        Router { routes: BTreeMap::new(), dist: None }
    }

    /// Attach a distributed-tier coordinator; the server then answers
    /// `POST /v1/dist/push_delta`, `GET /v1/dist/pull_w`, and
    /// `GET /v1/dist/stats` against it.
    pub fn with_dist(mut self, coord: Arc<crate::dist::DistCoordinator>) -> Router {
        self.dist = Some(coord);
        self
    }

    /// The attached coordinator, if any.
    pub fn dist(&self) -> Option<&Arc<crate::dist::DistCoordinator>> {
        self.dist.as_ref()
    }

    /// Look up a route by name.
    pub fn route(&self, name: &str) -> Option<&Route> {
        self.routes.get(name)
    }

    /// The sole route, when exactly one exists (lets single-tenant
    /// clients omit the `route` selector).
    pub fn sole_route(&self) -> Option<&Route> {
        if self.routes.len() == 1 {
            self.routes.values().next()
        } else {
            None
        }
    }

    /// Route names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.routes.keys().map(String::as_str).collect()
    }

    /// Number of routes.
    pub fn len(&self) -> usize {
        self.routes.len()
    }

    /// Whether the router has no routes (true only for the
    /// [`Router::empty`] dist-coordinator shape).
    pub fn is_empty(&self) -> bool {
        self.routes.is_empty()
    }

    /// Sync every route's serving metrics into `reg` (called by
    /// `GET /metrics` at scrape time).  Monotonic totals go through
    /// `Counter::set_floor` (race-safe under concurrent scrapes);
    /// rates, latency quantiles, and registry depth are gauges keyed
    /// by a `route` label.
    pub fn publish_metrics(&self, reg: &crate::obs::MetricsRegistry) {
        for (name, r) in &self.routes {
            let rep = r.report();
            reg.counter(
                &format!("passcode_route_requests_total{{route=\"{name}\"}}"),
                "Requests scored by the route",
            )
            .set_floor(rep.requests);
            reg.gauge(
                &format!("passcode_route_qps{{route=\"{name}\"}}"),
                "Requests per second over the route's lifetime",
            )
            .set(rep.qps);
            let quantiles =
                [("0.5", rep.p50_secs), ("0.95", rep.p95_secs), ("0.99", rep.p99_secs)];
            for (q, v) in quantiles {
                reg.gauge(
                    &format!(
                        "passcode_route_latency_seconds{{route=\"{name}\",quantile=\"{q}\"}}"
                    ),
                    "End-to-end scoring latency quantile",
                )
                .set(v);
            }
            reg.gauge(
                &format!("passcode_route_versions_alive{{route=\"{name}\"}}"),
                "Model versions retained by the route's registry",
            )
            .set(rep.versions_alive as f64);
            reg.gauge(
                &format!("passcode_route_model_epoch{{route=\"{name}\"}}"),
                "Registry epoch of the currently served model",
            )
            .set(rep.epoch as f64);
        }
    }

    /// Per-route stats as JSON: `{"routes": {name: report...}}`.
    pub fn stats_json(&self) -> Json {
        let routes = self
            .routes
            .iter()
            .map(|(name, r)| (name.clone(), r.report().to_json()))
            .collect();
        Json::obj(vec![("routes", Json::Obj(routes))])
    }

    /// Shut every route down; per-route final reports in name order.
    pub fn shutdown(self) -> Vec<(String, ThroughputReport)> {
        self.routes
            .into_iter()
            .map(|(name, r)| (name, r.shutdown()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model(tag: f64, d: usize) -> Model {
        Model {
            w: vec![tag; d],
            loss: "hinge".into(),
            c: 1.0,
            solver: "test".into(),
            dataset: "toy".into(),
        }
    }

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("passcode_net_router").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn config_parses_and_validates() {
        let cfg = RoutesConfig::from_json_text(
            r#"{"routes": [
                {"name": "a", "model": "a.json", "shards": 2, "max_batch": 16},
                {"name": "b", "dataset": "rcv1", "online": true,
                 "max_wait_us": 50, "online_min_rows": 10}
            ]}"#,
        )
        .unwrap();
        assert_eq!(cfg.routes.len(), 2);
        assert_eq!(cfg.routes[0].serve.shards, 2);
        assert_eq!(cfg.routes[0].serve.max_batch, 16);
        assert_eq!(cfg.routes[1].serve.max_wait, Duration::from_micros(50));
        assert!(cfg.routes[1].online);

        for bad in [
            r#"{"routes": []}"#,
            r#"{"routes": [{"name": "a"}]}"#,
            r#"{"routes": [{"name": "a", "model": "m", "dataset": "d"}]}"#,
            r#"{"routes": [{"name": "a/b", "model": "m"}]}"#,
            r#"{"routes": [{"name": "a", "model": "m"},
                            {"name": "a", "model": "m"}]}"#,
            // Typo'd keys fail loudly, like typo'd CLI flags.
            r#"{"routes": [{"name": "a", "model": "m", "shard": 4}]}"#,
            // online_min_rows above the window would never trigger.
            r#"{"routes": [{"name": "a", "model": "m", "online": true,
                             "online_window": 100}]}"#,
        ] {
            assert!(RoutesConfig::from_json_text(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn routes_are_isolated_and_publishable() {
        let dir = tmpdir("isolated");
        let path_b2 = dir.join("b2.json");
        toy_model(5.0, 4).save(&path_b2).unwrap();

        let engine_a = ServeEngine::start(toy_model(1.0, 4), None, &ServeConfig::default());
        let mut router = Router::single("a", engine_a);
        let engine_b = ServeEngine::start(toy_model(2.0, 4), None, &ServeConfig::default());
        router.routes.insert(
            "b".to_string(),
            Route {
                name: "b".into(),
                engine: engine_b,
                trainer: None,
                trainer_stop: Arc::new(AtomicBool::new(false)),
                trainer_loop: None,
            },
        );
        assert_eq!(router.names(), vec!["a", "b"]);
        assert!(router.sole_route().is_none());

        let rows = vec![(vec![0u32], vec![1.0])];
        assert_eq!(router.route("a").unwrap().score(&rows)[0].margin, 1.0);
        assert_eq!(router.route("b").unwrap().score(&rows)[0].margin, 2.0);

        // Publish on b: a's epoch and scores are untouched.
        let epoch = router.route("b").unwrap().publish_from_file(path_b2.to_str().unwrap()).unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(router.route("b").unwrap().score(&rows)[0].margin, 5.0);
        assert_eq!(router.route("a").unwrap().score(&rows)[0].margin, 1.0);
        assert_eq!(router.route("a").unwrap().report().epoch, 0);
        assert_eq!(router.route("b").unwrap().report().epoch, 1);
        assert_eq!(router.route("b").unwrap().report().versions_alive, 2);

        // Dimension-mismatched publishes are refused.
        let bad = dir.join("bad.json");
        toy_model(1.0, 9).save(&bad).unwrap();
        assert!(router
            .route("a")
            .unwrap()
            .publish_from_file(bad.to_str().unwrap())
            .is_err());

        let stats = router.stats_json();
        let routes = stats.get("routes").unwrap();
        assert!(routes.opt("a").is_some() && routes.opt("b").is_some());

        let reports = router.shutdown();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].0, "a");
        assert_eq!(reports[0].1.requests, 2);
    }

    #[test]
    fn route_start_from_model_file_and_ingest_without_trainer() {
        let dir = tmpdir("from_file");
        let path = dir.join("m.json");
        toy_model(3.0, 2).save(&path).unwrap();
        let spec = RouteSpec {
            name: "m".into(),
            model: Some(path.to_str().unwrap().to_string()),
            ..Default::default()
        };
        let route = Route::start(&spec).unwrap();
        assert_eq!(route.score(&[(vec![1], vec![2.0])])[0].margin, 6.0);
        // No trainer attached: ingest is a no-op.
        assert_eq!(route.ingest(&[(vec![0], vec![1.0])], &[1.0]), 0);
        route.shutdown();

        // Missing file surfaces the route name in the error.
        let missing = RouteSpec {
            name: "ghost".into(),
            model: Some(dir.join("nope.json").to_str().unwrap().to_string()),
            ..Default::default()
        };
        let err = format!("{:#}", Route::start(&missing).unwrap_err());
        assert!(err.contains("ghost"), "{err}");
    }
}
