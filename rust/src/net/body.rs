//! Score-request body decoding: JSON (single or batch) and LIBSVM text.
//!
//! `POST /v1/score` accepts either encoding; the decoder sniffs the
//! `Content-Type` first and falls back on the payload's first byte, so
//! `curl -d '{"idx":[1],"vals":[2.0]}'` and piping a `.svm` file both
//! work without ceremony.
//!
//! JSON forms (indices are 0-based, strictly increasing):
//!
//! ```json
//! {"route": "a", "idx": [0, 7], "vals": [0.5, -1.0]}
//! {"route": "a", "rows": [{"idx": [0], "vals": [1.0]},
//!                          {"idx": [2, 3], "vals": [1.0, 2.0]}]}
//! ```
//!
//! LIBSVM form (1-based indices, one row per line, labels required by
//! the format and carried through so callers can report accuracy):
//!
//! ```text
//! +1 1:0.5 8:-1.0
//! -1 3:1.0
//! ```

use anyhow::{bail, ensure, Context, Result};

use crate::data::libsvm;
use crate::util::Json;

/// One decoded sparse row: parallel `(indices, values)`.
pub type SparseRow = (Vec<u32>, Vec<f64>);

/// The decoded payload of a score request.
#[derive(Debug, Clone, Default)]
pub struct ScoreBody {
    /// Route/tenant name, when the body carries one (`"route"` field;
    /// LIBSVM bodies rely on the `?route=` query parameter instead).
    pub route: Option<String>,
    /// Raw (unfolded) sparse rows to score.
    pub rows: Vec<SparseRow>,
    /// Ground-truth labels, when the encoding carries them (LIBSVM).
    pub labels: Option<Vec<f64>>,
}

/// Decode one `POST /v1/score` body.
pub fn decode_score_body(content_type: Option<&str>, body: &[u8]) -> Result<ScoreBody> {
    ensure!(!body.is_empty(), "empty request body");
    let looks_json = match content_type {
        Some(ct) if ct.contains("json") => true,
        Some(ct) if ct.starts_with("text/") => false,
        _ => body.iter().find(|b| !b.is_ascii_whitespace()) == Some(&b'{'),
    };
    if looks_json {
        decode_json(body)
    } else {
        decode_libsvm(body)
    }
}

fn decode_json(body: &[u8]) -> Result<ScoreBody> {
    let text = std::str::from_utf8(body).context("body is not UTF-8")?;
    let v = Json::parse(text).context("malformed JSON body")?;
    let route = match v.opt("route") {
        Some(r) => Some(r.as_str().context("\"route\" must be a string")?.to_string()),
        None => None,
    };
    let rows = match v.opt("rows") {
        Some(rows) => rows
            .as_arr()
            .context("\"rows\" must be an array")?
            .iter()
            .enumerate()
            .map(|(i, r)| decode_json_row(r).with_context(|| format!("rows[{i}]")))
            .collect::<Result<Vec<SparseRow>>>()?,
        None => vec![decode_json_row(&v)?],
    };
    ensure!(!rows.is_empty(), "\"rows\" is empty");
    Ok(ScoreBody { route, rows, labels: None })
}

fn decode_json_row(v: &Json) -> Result<SparseRow> {
    let idx: Vec<u32> = v
        .get("idx")?
        .as_arr()?
        .iter()
        .map(|j| {
            let u = j.as_usize()?;
            ensure!(u <= u32::MAX as usize, "index {u} exceeds u32");
            Ok(u as u32)
        })
        .collect::<Result<_>>()?;
    let vals: Vec<f64> = v
        .get("vals")?
        .as_arr()?
        .iter()
        .map(|x| x.as_f64())
        .collect::<Result<_>>()?;
    ensure!(
        idx.len() == vals.len(),
        "idx has {} entries, vals has {}",
        idx.len(),
        vals.len()
    );
    if !idx.windows(2).all(|w| w[0] < w[1]) {
        bail!("indices must be strictly increasing");
    }
    if let Some(bad) = vals.iter().find(|x| !x.is_finite()) {
        bail!("non-finite value {bad}");
    }
    Ok((idx, vals))
}

fn decode_libsvm(body: &[u8]) -> Result<ScoreBody> {
    // Reuse the dataset reader for validation (1-based, sorted, well
    // formed); `raw_row` un-folds the stored x = y·ẋ back to features.
    let ds = libsvm::parse_reader(body, "http", 0).context("malformed LIBSVM body")?;
    ensure!(ds.n() > 0, "LIBSVM body has no rows");
    let rows = (0..ds.n()).map(|i| ds.raw_row(i)).collect();
    Ok(ScoreBody { route: None, rows, labels: Some(ds.y.clone()) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_json_row() {
        let b = decode_score_body(
            Some("application/json"),
            br#"{"route": "a", "idx": [0, 7], "vals": [0.5, -1.0]}"#,
        )
        .unwrap();
        assert_eq!(b.route.as_deref(), Some("a"));
        assert_eq!(b.rows, vec![(vec![0, 7], vec![0.5, -1.0])]);
        assert!(b.labels.is_none());
    }

    #[test]
    fn batch_json_rows() {
        let b = decode_score_body(
            None, // sniffed from the leading '{'
            br#"{"rows": [{"idx": [0], "vals": [1.0]}, {"idx": [2, 3], "vals": [1.0, 2.0]}]}"#,
        )
        .unwrap();
        assert!(b.route.is_none());
        assert_eq!(b.rows.len(), 2);
        assert_eq!(b.rows[1], (vec![2, 3], vec![1.0, 2.0]));
    }

    #[test]
    fn libsvm_rows_carry_labels() {
        let b = decode_score_body(Some("text/plain"), b"+1 1:0.5 8:-1.0\n-1 3:1.0\n").unwrap();
        assert_eq!(b.rows.len(), 2);
        // 1-based LIBSVM index 1 -> feature 0; raw values are unfolded.
        assert_eq!(b.rows[0], (vec![0, 7], vec![0.5, -1.0]));
        assert_eq!(b.rows[1], (vec![2], vec![1.0]));
        assert_eq!(b.labels, Some(vec![1.0, -1.0]));
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode_score_body(None, b"").is_err());
        assert!(decode_score_body(None, b"{").is_err());
        assert!(decode_score_body(None, br#"{"idx": [0], "vals": [1.0, 2.0]}"#).is_err());
        assert!(decode_score_body(None, br#"{"idx": [3, 1], "vals": [1.0, 2.0]}"#).is_err());
        assert!(decode_score_body(None, br#"{"rows": []}"#).is_err());
        assert!(decode_score_body(Some("text/plain"), b"+1 0:1.0\n").is_err());
        assert!(decode_score_body(Some("text/plain"), b"\n# nothing\n").is_err());
    }
}
