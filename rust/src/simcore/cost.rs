//! Per-operation cost model for the multicore simulator.
//!
//! The paper's testbed is a 2-socket × 10-core Xeon; this image has one
//! core, so wall-clock speedups cannot be *measured* — they are
//! *modelled* (DESIGN.md §3).  A coordinate update of row `i` decomposes
//! into (cf. Algorithm 2):
//!
//! ```text
//!   t_update(i) = t_fixed                          (pick + subproblem)
//!               + nnz_i · t_read                   (step 2: read ŵ, dot)
//!               + nnz_i · t_write[mechanism]       (step 3: publish Δα x_i)
//!               + lock overhead + contention       (Lock only)
//! ```
//!
//! The constants default to values calibrated on this host by
//! [`calibrate::measure`](super::calibrate::measure); the *ratios* —
//! CAS ≈ 2–4× a plain store, lock acquire+release ≈ 20–60× — are what
//! drive Table 1's shape and are stable across x86 parts.

/// Cost constants, all in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Fixed per-update work: RNG, subproblem solve, bookkeeping.
    pub t_fixed: f64,
    /// Per-nonzero read + multiply-add in the dot product.
    pub t_read: f64,
    /// Per-nonzero plain (wild) read-modify-write.
    pub t_write_plain: f64,
    /// Per-nonzero atomic CAS add (uncontended).
    pub t_write_atomic: f64,
    /// Extra CAS retries under contention, per contending core.
    pub t_cas_retry: f64,
    /// Acquire + release of one feature spinlock (uncontended).
    pub t_lock_pair: f64,
    /// Spin-wait penalty per blocked acquisition attempt.
    pub t_lock_contended: f64,
    /// Shared-memory bandwidth drag: every active core slows all others
    /// by this fraction (cacheline traffic + DRAM contention).  This is
    /// what makes the paper's Wild speedup sublinear (7.4× at 10 cores,
    /// not 10×).
    pub bandwidth_drag: f64,
    /// NUMA: multiplier on the per-nonzero read cost when the feature's
    /// cacheline was last written by a core on *another* socket (paper
    /// §3.3 "Thread Affinity": remote-socket access is slower; the
    /// paper pins all threads to one socket to avoid it).
    pub numa_remote_penalty: f64,
}

impl Default for CostModel {
    /// Host-calibrated defaults (see `passcode calibrate`); ratios match
    /// published x86 microarchitectural numbers.
    fn default() -> Self {
        Self {
            t_fixed: 25.0,
            t_read: 1.0,
            t_write_plain: 1.2,
            // Uncontended lock-free add ≈ plain store + a fraction: the
            // cacheline fetch dominates both on x86.  The paper measures
            // Atomic only ~7% slower than Wild end-to-end (Table 1).
            t_write_atomic: 1.6,
            t_cas_retry: 8.0,
            t_lock_pair: 16.0,
            t_lock_contended: 60.0,
            bandwidth_drag: 0.030,
            // ~1.6× remote:local latency ratio — typical 2-socket Xeon.
            numa_remote_penalty: 1.6,
        }
    }
}

impl CostModel {
    /// Service time (ns) of one update of a row with `nnz` nonzeros under
    /// the given mechanism, before contention effects.
    pub fn base_update_ns(&self, nnz: usize, mech: Mechanism) -> f64 {
        let nnz = nnz as f64;
        let write = match mech {
            Mechanism::Wild => self.t_write_plain,
            Mechanism::Atomic => self.t_write_atomic,
            Mechanism::Lock => self.t_write_plain,
        };
        let lock = match mech {
            Mechanism::Lock => nnz * self.t_lock_pair,
            _ => 0.0,
        };
        self.t_fixed + nnz * (self.t_read + write) + lock
    }
}

/// The three write mechanisms (simulator-side mirror of
/// [`crate::solver::MemoryModel`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mechanism {
    Lock,
    Atomic,
    Wild,
}

impl From<crate::solver::MemoryModel> for Mechanism {
    fn from(m: crate::solver::MemoryModel) -> Self {
        match m {
            crate::solver::MemoryModel::Lock => Mechanism::Lock,
            crate::solver::MemoryModel::Atomic => Mechanism::Atomic,
            crate::solver::MemoryModel::Wild => Mechanism::Wild,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_costs_ordered_wild_atomic_lock() {
        let c = CostModel::default();
        let nnz = 50;
        let wild = c.base_update_ns(nnz, Mechanism::Wild);
        let atomic = c.base_update_ns(nnz, Mechanism::Atomic);
        let lock = c.base_update_ns(nnz, Mechanism::Lock);
        assert!(wild < atomic, "wild {wild} !< atomic {atomic}");
        assert!(atomic < lock, "atomic {atomic} !< lock {lock}");
    }

    #[test]
    fn cost_scales_linearly_in_nnz() {
        let c = CostModel::default();
        let a = c.base_update_ns(10, Mechanism::Wild);
        let b = c.base_update_ns(20, Mechanism::Wild);
        let inc = b - a;
        let d = c.base_update_ns(30, Mechanism::Wild);
        assert!((d - b - inc).abs() < 1e-9);
    }
}
