//! Cost-model calibration: microbenchmarks on *this* host for the
//! constants in [`CostModel`].  Run via `passcode calibrate`.
//!
//! Each probe times a tight loop over a scattered f64 array sized to
//! spill L1 (so the numbers include realistic cache behaviour), with
//! enough iterations to drown scheduler noise on a busy 1-core box.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::util::{Pcg32, Timer};

use super::cost::CostModel;

const ARRAY: usize = 1 << 16; // 512 KiB of f64 — beyond L1
const ITERS: usize = 2_000_000;

fn scattered_indices() -> Vec<usize> {
    let mut rng = Pcg32::new(0xCA11B, 7);
    (0..ITERS).map(|_| rng.gen_range(ARRAY)).collect()
}

/// ns/op of a plain read-multiply-accumulate (the dot-product step).
pub fn probe_read() -> f64 {
    let v = vec![1.0f64; ARRAY];
    let idx = scattered_indices();
    let t = Timer::start();
    let mut acc = 0.0;
    for &i in &idx {
        acc += v[i] * 1.0001;
    }
    let secs = t.secs();
    std::hint::black_box(acc);
    secs * 1e9 / ITERS as f64
}

/// ns/op of a plain (relaxed) read-modify-write — the Wild step 3.
pub fn probe_write_plain() -> f64 {
    let v: Vec<AtomicU64> =
        (0..ARRAY).map(|_| AtomicU64::new(1f64.to_bits())).collect();
    let idx = scattered_indices();
    let t = Timer::start();
    for &i in &idx {
        let cur = f64::from_bits(v[i].load(Ordering::Relaxed));
        v[i].store((cur + 1.0).to_bits(), Ordering::Relaxed);
    }
    t.secs() * 1e9 / ITERS as f64
}

/// ns/op of a CAS-loop add — the Atomic step 3 (uncontended).
pub fn probe_write_atomic() -> f64 {
    let v: Vec<AtomicU64> =
        (0..ARRAY).map(|_| AtomicU64::new(1f64.to_bits())).collect();
    let idx = scattered_indices();
    let t = Timer::start();
    for &i in &idx {
        let cell = &v[i];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + 1.0).to_bits();
            match cell.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(a) => cur = a,
            }
        }
    }
    t.secs() * 1e9 / ITERS as f64
}

/// ns per acquire+release of one spinlock (uncontended).
pub fn probe_lock_pair() -> f64 {
    let locks: Vec<AtomicBool> =
        (0..ARRAY).map(|_| AtomicBool::new(false)).collect();
    let idx = scattered_indices();
    let t = Timer::start();
    for &i in &idx {
        while locks[i]
            .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            std::hint::spin_loop();
        }
        locks[i].store(false, Ordering::Release);
    }
    t.secs() * 1e9 / ITERS as f64
}

/// Measure everything and assemble a [`CostModel`].  Contention constants
/// (`t_cas_retry`, `t_lock_contended`) cannot be measured on one core —
/// they keep literature-ratio defaults scaled by the measured base costs.
pub fn measure() -> CostModel {
    let t_read = probe_read();
    let t_write_plain = probe_write_plain();
    let t_write_atomic = probe_write_atomic();
    let t_lock_pair = probe_lock_pair();
    let d = CostModel::default();
    CostModel {
        t_fixed: d.t_fixed,
        t_read,
        t_write_plain,
        t_write_atomic,
        t_cas_retry: 2.0 * t_write_atomic,
        t_lock_pair,
        t_lock_contended: (d.t_lock_contended / d.t_lock_pair) * t_lock_pair,
        bandwidth_drag: d.bandwidth_drag,
        numa_remote_penalty: d.numa_remote_penalty,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probes_return_positive_nanoseconds() {
        // Keep it quick: just the cheapest probe in unit tests.
        let r = probe_read();
        assert!(r > 0.0 && r < 1_000.0, "implausible read cost {r} ns");
    }

    #[test]
    fn measured_model_is_ordered() {
        let m = measure();
        assert!(m.t_read > 0.0);
        assert!(m.t_write_atomic >= m.t_write_plain * 0.5,
            "CAS {} vs plain {}", m.t_write_atomic, m.t_write_plain);
        assert!(m.t_lock_pair > 0.0);
    }
}
