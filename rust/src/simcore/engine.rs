//! Discrete-event simulator of PASSCoDe on a p-core shared-memory machine.
//!
//! This is the hardware substitution for the paper's 10-core Xeon
//! (DESIGN.md §3): the host has one physical core, so parallel wall-clock
//! behaviour is *simulated* with faithful semantics:
//!
//! * every virtual core owns a random block of coordinates (paper §3.3)
//!   and carries a local clock advanced by the [`CostModel`];
//! * a read at virtual time `t` sees exactly the writes **committed**
//!   `≤ t` — bounded staleness (the paper's `τ`) emerges from update
//!   latency instead of being assumed;
//! * `Wild` commits are overwrites: concurrent commits that land inside a
//!   read-modify-write window are lost (counted in
//!   [`SimReport::lost_writes`]) — the paper's Eq.-6 memory conflicts;
//! * `Atomic` commits are additive (never lost) but pay CAS costs plus a
//!   contention-dependent retry penalty;
//! * `Lock` serializes overlapping feature sets through per-feature lock
//!   timelines (ordered acquisition — no deadlock), paying the lock
//!   overhead that makes it slower than serial DCD (Table 1).
//!
//! The simulation itself is deterministic given a seed: every experiment
//! in EXPERIMENTS.md §Table-1/§Fig-d replays exactly.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::data::Dataset;
use crate::loss::{Loss, MIN_DELTA};
use crate::util::Pcg32;

use super::cost::{CostModel, Mechanism};

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Number of virtual cores.
    pub cores: usize,
    /// Epochs (each core does one pass over its block per epoch).
    pub epochs: usize,
    pub seed: u64,
    pub cost: CostModel,
    pub mechanism: Mechanism,
    /// NUMA sockets the cores are spread over (contiguous halves).
    /// 1 = the paper's recommended same-socket affinity (§3.3); 2 models
    /// threads spread across both sockets: a read of a feature last
    /// written by the other socket pays `cost.numa_remote_penalty`.
    pub sockets: usize,
}

impl SimConfig {
    /// One-socket (paper-affinity) configuration.
    pub fn new(cores: usize, epochs: usize, seed: u64, mechanism: Mechanism) -> Self {
        Self { cores, epochs, seed, cost: CostModel::default(), mechanism, sockets: 1 }
    }
}

/// Aggregate simulation report.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Final dual iterate.
    pub alpha: Vec<f64>,
    /// Final shared-memory primal vector (all commits applied).
    pub w: Vec<f64>,
    /// Virtual wall-clock of the run (ns): max core finish time.
    pub virtual_ns: f64,
    /// Total coordinate updates simulated.
    pub updates: u64,
    /// Wild only: writes clobbered by overlapping commits.
    pub lost_writes: u64,
    /// Atomic only: CAS retries charged.
    pub cas_retries: u64,
    /// Lock only: total ns spent waiting for locks.
    pub lock_wait_ns: f64,
    /// Mean number of in-flight updates observed at read time (≈ τ).
    pub mean_staleness: f64,
    /// Per-epoch snapshots: (epoch, virtual_ns) at leader-core boundaries.
    pub epoch_marks: Vec<(usize, f64)>,
}

/// One pending commit to shared memory (commit time lives in the heap key).
#[derive(Debug, Clone, Copy)]
struct Commit {
    feature: u32,
    /// Additive delta (Atomic/Lock) or overwrite delta (Wild).
    delta: f64,
    /// Wild: the memory value of the feature captured at this feature
    /// write's RMW read instant; the commit *overwrites* with
    /// `base + delta`, silently erasing anything that landed since
    /// `created` — the real RMW's lost-update semantics, with the race
    /// window ≈ one `t_write` (commits from updates that start after the
    /// snapshot but land inside the window are a second-order miss).
    base: f64,
    /// Virtual time the base snapshot was taken (the RMW read instant).
    created: f64,
    overwrite: bool,
}

// BinaryHeap is a max-heap; order commits by smallest time first.
#[derive(Debug, PartialEq, Clone, Copy)]
struct ByTime(f64, usize);
impl Eq for ByTime {}
impl PartialOrd for ByTime {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ByTime {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

/// Run the simulation.
pub fn simulate<L: Loss>(ds: &Dataset, loss: &L, cfg: &SimConfig) -> SimReport {
    let n = ds.n();
    let d = ds.d();
    let p = cfg.cores.max(1);
    let qii = ds.x.all_row_sqnorms();

    // Random partition into p blocks (same scheme as the real solver).
    let mut rng = Pcg32::new(cfg.seed, 0x51AC);
    let perm = rng.permutation(n);
    let mut blocks: Vec<Vec<usize>> = Vec::with_capacity(p);
    {
        let base = n / p;
        let rem = n % p;
        let mut start = 0;
        for t in 0..p {
            let len = base + usize::from(t < rem);
            blocks.push(perm[start..start + len].to_vec());
            start += len;
        }
    }

    let sockets = cfg.sockets.max(1);
    let socket_of = |core: usize| core * sockets / p;

    // Shared memory state (commit-ordered application).
    let mut w = vec![0.0f64; d];
    let mut last_commit_time = vec![f64::NEG_INFINITY; d];
    // Socket that last wrote each feature's cacheline (NUMA model).
    let mut last_socket: Vec<u8> = vec![0; if sockets > 1 { d } else { 0 }];
    let mut alpha = vec![0.0f64; n];
    let mut commits: BinaryHeap<Reverse<ByTime>> = BinaryHeap::new();
    let mut commit_pool: Vec<Commit> = Vec::new();
    let mut free_slots: Vec<usize> = Vec::new();

    // Lock timelines (Lock mechanism only).
    let mut lock_until = vec![0.0f64; if cfg.mechanism == Mechanism::Lock { d } else { 0 }];

    // Per-core cursors.
    struct Core {
        clock: f64,
        order: Vec<usize>,
        pos: usize,
        epoch: usize,
        rng: Pcg32,
    }
    let mut cores: Vec<Core> = (0..p)
        .map(|t| {
            let mut rng = Pcg32::new(cfg.seed, 0xC0DE + t as u64);
            let mut order = blocks[t].clone();
            rng.shuffle(&mut order);
            Core { clock: 0.0, order, pos: 0, epoch: 0, rng }
        })
        .collect();

    // Ready queue of cores ordered by local clock.
    let mut ready: BinaryHeap<Reverse<ByTime>> = (0..p)
        .map(|t| Reverse(ByTime(0.0, t)))
        .collect();

    let mut report = SimReport {
        alpha: Vec::new(),
        w: Vec::new(),
        virtual_ns: 0.0,
        updates: 0,
        lost_writes: 0,
        cas_retries: 0,
        lock_wait_ns: 0.0,
        mean_staleness: 0.0,
        epoch_marks: Vec::new(),
    };
    let mut staleness_sum: f64 = 0.0;
    let mut staleness_obs: u64 = 0;

    // Apply all commits with time ≤ t.
    macro_rules! drain_commits {
        ($t:expr) => {
            while let Some(&Reverse(ByTime(ct, slot))) = commits.peek() {
                if ct > $t {
                    break;
                }
                commits.pop();
                let c = commit_pool[slot];
                free_slots.push(slot);
                let j = c.feature as usize;
                if c.overwrite {
                    if last_commit_time[j] > c.created {
                        // We clobber whoever landed after our snapshot.
                        report.lost_writes += 1;
                    }
                    // True lost-update semantics: overwrite with
                    // base-at-read + delta, erasing interleaved commits.
                    w[j] = c.base + c.delta;
                } else {
                    w[j] += c.delta;
                }
                last_commit_time[j] = ct;
            }
        };
    }

    while let Some(Reverse(ByTime(t, core_id))) = ready.pop() {
        let core = &mut cores[core_id];
        if core.epoch >= cfg.epochs {
            continue;
        }
        // Fetch next coordinate; roll epochs.
        if core.pos >= core.order.len() {
            core.pos = 0;
            core.epoch += 1;
            let seed_rng = &mut core.rng;
            seed_rng.shuffle(&mut core.order);
            if core_id == 0 {
                report.epoch_marks.push((core.epoch, t));
            }
            if core.epoch >= cfg.epochs {
                report.virtual_ns = report.virtual_ns.max(core.clock);
                continue;
            }
        }
        let i = core.order[core.pos];
        core.pos += 1;
        let q = qii[i];
        if q <= 0.0 {
            ready.push(Reverse(ByTime(core.clock, core_id)));
            continue;
        }
        let (idx, vals) = ds.x.row(i);
        let nnz = idx.len();

        // ---- Lock: wait for every feature lock (ordered acquisition) --
        let mut start = t;
        if cfg.mechanism == Mechanism::Lock {
            let mut free_at = t;
            for &j in idx {
                free_at = free_at.max(lock_until[j as usize]);
            }
            if free_at > t {
                report.lock_wait_ns += (free_at - t)
                    + cfg.cost.t_lock_contended;
                start = free_at + cfg.cost.t_lock_contended;
            }
        }

        // ---- Read phase: memory as of `start` -------------------------
        drain_commits!(start);
        staleness_sum += commits.len() as f64;
        staleness_obs += 1;
        let mut wx = 0.0;
        for (j, v) in idx.iter().zip(vals) {
            wx += w[*j as usize] * v;
        }
        let a_old = alpha[i];
        let a_new = loss.solve_subproblem(a_old, wx, q);
        let delta = a_new - a_old;
        report.updates += 1;

        // ---- Service time + contention model --------------------------
        // Bandwidth drag: p concurrently-active cores slow each other
        // (cacheline traffic) — the source of sublinear Wild scaling.
        let drag = 1.0 + cfg.cost.bandwidth_drag * (p as f64 - 1.0);
        let mut service = cfg.cost.base_update_ns(nnz, cfg.mechanism) * drag;
        // NUMA: remote-socket cachelines cost extra to read (§3.3).
        if sockets > 1 {
            let my_socket = socket_of(core_id) as u8;
            let remote = idx
                .iter()
                .filter(|&&j| last_socket[j as usize] != my_socket)
                .count();
            service += remote as f64
                * cfg.cost.t_read
                * (cfg.cost.numa_remote_penalty - 1.0);
        }
        let read_end = start + cfg.cost.t_fixed + nnz as f64 * cfg.cost.t_read;

        if delta.abs() > MIN_DELTA {
            alpha[i] = a_new;
            // Schedule the per-feature writes.
            let wstep = match cfg.mechanism {
                Mechanism::Wild => cfg.cost.t_write_plain,
                Mechanism::Atomic => cfg.cost.t_write_atomic,
                Mechanism::Lock => cfg.cost.t_write_plain,
            };
            for (k, (j, v)) in idx.iter().zip(vals).enumerate() {
                let jj = *j as usize;
                let wr = read_end + k as f64 * wstep;
                let mut wc = wr + wstep;
                if cfg.mechanism == Mechanism::Atomic {
                    // Contention heuristic: if someone committed to this
                    // feature within a CAS window before our write, we
                    // retry once.
                    if last_commit_time[jj] > wr - 4.0 * wstep {
                        report.cas_retries += 1;
                        service += cfg.cost.t_cas_retry;
                        wc += cfg.cost.t_cas_retry;
                    }
                }
                // Advance memory to this feature-write's read instant so
                // the Wild base snapshot covers only the ~t_write RMW
                // window (commits from not-yet-simulated updates that
                // would land inside (t, wr) are a second-order miss).
                drain_commits!(wr);
                if sockets > 1 {
                    last_socket[jj] = socket_of(core_id) as u8;
                }
                let commit = Commit {
                    feature: *j,
                    delta: delta * v,
                    base: w[jj],
                    created: wr,
                    overwrite: cfg.mechanism == Mechanism::Wild,
                };
                let slot = if let Some(s) = free_slots.pop() {
                    commit_pool[s] = commit;
                    s
                } else {
                    commit_pool.push(commit);
                    commit_pool.len() - 1
                };
                commits.push(Reverse(ByTime(wc, slot)));
            }
        }

        let end = start + service;
        if cfg.mechanism == Mechanism::Lock {
            for &j in idx {
                lock_until[j as usize] = end;
            }
        }
        core.clock = end;
        report.virtual_ns = report.virtual_ns.max(end);
        ready.push(Reverse(ByTime(end, core_id)));
    }

    // Flush everything and finish.
    drain_commits!(f64::INFINITY);
    report.alpha = alpha;
    report.w = w;
    report.mean_staleness = if staleness_obs > 0 {
        staleness_sum / staleness_obs as f64
    } else {
        0.0
    };
    report
}

/// Convenience: simulated serial reference time (1 core, wild costs —
/// the denominator of the paper's speedup definition §5.3).
pub fn serial_reference_ns<L: Loss>(
    ds: &Dataset,
    loss: &L,
    epochs: usize,
    seed: u64,
    cost: &CostModel,
) -> f64 {
    let cfg = SimConfig {
        cores: 1,
        epochs,
        seed,
        cost: *cost,
        mechanism: Mechanism::Wild, sockets: 1, };
    simulate(ds, loss, &cfg).virtual_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::eval;
    use crate::loss::Hinge;

    fn ds() -> (Dataset, f64) {
        let (tr, _, c) = registry::load("rcv1", 0.02).unwrap();
        (tr, c)
    }

    fn cfg(cores: usize, mech: Mechanism, epochs: usize) -> SimConfig {
        SimConfig {
            cores,
            epochs,
            seed: 9,
            cost: CostModel::default(),
            mechanism: mech, sockets: 1, }
    }

    #[test]
    fn single_core_wild_matches_serial_semantics() {
        // One virtual core has no concurrency: no lost writes, and the
        // final w must satisfy Eq. 3 exactly.
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        let r = simulate(&ds, &loss, &cfg(1, Mechanism::Wild, 10));
        assert_eq!(r.lost_writes, 0);
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "Eq. 3 violated on 1 core: {err}");
    }

    #[test]
    fn all_mechanisms_converge_in_simulation() {
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        for mech in [Mechanism::Lock, Mechanism::Atomic, Mechanism::Wild] {
            let r = simulate(&ds, &loss, &cfg(8, mech, 30));
            let gap = eval::duality_gap(&ds, &loss, &r.alpha);
            let p = eval::primal_objective(&ds, &loss, &r.w);
            assert!(
                gap < 0.05 * p.abs().max(1.0),
                "{mech:?} gap {gap} (P={p})"
            );
        }
    }

    #[test]
    fn atomic_never_loses_writes_and_obeys_eq3() {
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        let r = simulate(&ds, &loss, &cfg(8, Mechanism::Atomic, 10));
        assert_eq!(r.lost_writes, 0);
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "atomic Eq. 3 error {err}");
    }

    #[test]
    fn wild_on_many_cores_loses_writes() {
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        let r = simulate(&ds, &loss, &cfg(10, Mechanism::Wild, 20));
        assert!(r.lost_writes > 0, "no memory conflicts on 10 cores?");
    }

    #[test]
    fn speedup_shape_matches_table1() {
        // The paper's Table 1 shape: Wild ≥ Atomic ≫ Lock, and Lock is
        // slower than serial.
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        let epochs = 10;
        let serial =
            serial_reference_ns(&ds, &loss, epochs, 9, &CostModel::default());
        let t = |mech| {
            simulate(&ds, &loss, &cfg(10, mech, epochs)).virtual_ns
        };
        let (lock, atomic, wild) = (
            t(Mechanism::Lock),
            t(Mechanism::Atomic),
            t(Mechanism::Wild),
        );
        let s = |x: f64| serial / x;
        assert!(s(wild) > 4.0, "wild speedup {} too low", s(wild));
        assert!(s(atomic) > 3.0, "atomic speedup {}", s(atomic));
        assert!(
            s(wild) >= s(atomic),
            "wild {} not ≥ atomic {}",
            s(wild),
            s(atomic)
        );
        assert!(s(lock) < 1.0, "lock speedup {} not < 1", s(lock));
    }

    #[test]
    fn more_cores_more_staleness() {
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        let s2 = simulate(&ds, &loss, &cfg(2, Mechanism::Atomic, 5));
        let s10 = simulate(&ds, &loss, &cfg(10, Mechanism::Atomic, 5));
        assert!(
            s10.mean_staleness > s2.mean_staleness,
            "staleness did not grow: {} vs {}",
            s2.mean_staleness,
            s10.mean_staleness
        );
    }

    #[test]
    fn simulation_is_deterministic() {
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        let a = simulate(&ds, &loss, &cfg(4, Mechanism::Wild, 5));
        let b = simulate(&ds, &loss, &cfg(4, Mechanism::Wild, 5));
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.virtual_ns, b.virtual_ns);
        assert_eq!(a.lost_writes, b.lost_writes);
    }

    #[test]
    fn numa_spread_is_slower_than_affinity() {
        // §3.3: threads across 2 sockets pay remote-cacheline reads.
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        let mut cfg1 = cfg(8, Mechanism::Wild, 5);
        cfg1.sockets = 1;
        let mut cfg2 = cfg1.clone();
        cfg2.sockets = 2;
        let t1 = simulate(&ds, &loss, &cfg1).virtual_ns;
        let t2 = simulate(&ds, &loss, &cfg2).virtual_ns;
        assert!(t2 > t1, "2-socket {t2} not slower than 1-socket {t1}");
        // but not absurdly slower (penalty is a read multiplier)
        assert!(t2 < 2.5 * t1, "NUMA penalty implausible: {}x", t2 / t1);
    }

    #[test]
    fn epoch_marks_are_monotone() {
        let (ds, c) = ds();
        let loss = Hinge::new(c);
        let r = simulate(&ds, &loss, &cfg(4, Mechanism::Atomic, 6));
        assert!(!r.epoch_marks.is_empty());
        for w in r.epoch_marks.windows(2) {
            assert!(w[1].0 > w[0].0 && w[1].1 >= w[0].1);
        }
    }
}
