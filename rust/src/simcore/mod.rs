//! Discrete-event multicore simulator — the hardware substitution for the
//! paper's 2×10-core Xeon testbed (DESIGN.md §3): virtual cores, a
//! calibrated per-operation cost model, and faithful lock/atomic/wild
//! shared-memory semantics (bounded staleness, lost writes, lock
//! serialization).

pub mod calibrate;
pub mod cost;
pub mod engine;

pub use cost::{CostModel, Mechanism};
pub use engine::{serial_reference_ns, simulate, SimConfig, SimReport};
