//! Loss library: primal losses, their conjugates, and the closed-form /
//! Newton solvers for the one-variable dual subproblem
//!
//! ```text
//!   Δα_i = argmin_δ  ½‖w + δ x_i‖² + ℓ*_i(−(α_i + δ))            (paper Eq. 4)
//! ```
//!
//! which, expanding the quadratic and dropping constants, is
//!
//! ```text
//!   argmin_δ  ½ q δ² + (w·x_i) δ + ℓ*_i(−(α_i + δ)),   q = ‖x_i‖².
//! ```
//!
//! Rows are label-folded (`x_i = y_i ẋ_i`), so every loss is a function of
//! the margin `z = w·x_i` and the dual variable lives in the conjugate's
//! domain (e.g. `[0, C]` for hinge).

use anyhow::{bail, Result};

pub mod hinge;
pub mod logistic;
pub mod square;
pub mod squared_hinge;

pub use hinge::Hinge;
pub use logistic::Logistic;
pub use square::Square;
pub use squared_hinge::SquaredHinge;

/// Which loss to optimize — the config/registry-facing key for the loss
/// library.  [`DynLoss::new`] turns a kind plus a penalty `C` into a
/// concrete [`Loss`] without monomorphizing the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Hinge loss (L1-SVM) — the paper's experimental workhorse.
    Hinge,
    /// Squared hinge (L2-SVM).
    SquaredHinge,
    /// ℓ2-regularized logistic regression.
    Logistic,
    /// Square loss (LS-SVM / ridge on folded labels).
    Square,
}

/// The one loss name table: canonical name first, aliases after.
const LOSS_NAMES: &[(&str, LossKind)] = &[
    ("hinge", LossKind::Hinge),
    ("squared-hinge", LossKind::SquaredHinge),
    ("squared_hinge", LossKind::SquaredHinge),
    ("l2svm", LossKind::SquaredHinge),
    ("logistic", LossKind::Logistic),
    ("logreg", LossKind::Logistic),
    ("square", LossKind::Square),
    ("ridge", LossKind::Square),
    ("lssvm", LossKind::Square),
];

impl LossKind {
    /// Every kind, in canonical order.
    pub const ALL: [LossKind; 4] = [
        LossKind::Hinge,
        LossKind::SquaredHinge,
        LossKind::Logistic,
        LossKind::Square,
    ];

    /// Parse a loss name (canonical or alias); unknown names list the
    /// valid ones.
    pub fn parse(s: &str) -> Result<LossKind> {
        for (name, kind) in LOSS_NAMES {
            if *name == s {
                return Ok(*kind);
            }
        }
        bail!(
            "unknown loss {s:?}; valid losses: {}",
            LOSS_NAMES
                .iter()
                .map(|(n, _)| *n)
                .collect::<Vec<_>>()
                .join(", ")
        )
    }

    /// Canonical name (what configs/logs print).
    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Hinge => "hinge",
            LossKind::SquaredHinge => "squared-hinge",
            LossKind::Logistic => "logistic",
            LossKind::Square => "square",
        }
    }
}

/// Runtime-dispatched loss: a [`LossKind`] plus its penalty `C`, packaged
/// as a concrete [`Loss`] implementation.  This is the type-erasure point
/// of the solver API — `solver::api::TrainSession` works for every loss
/// without a generic parameter, at the cost of one enum branch per loss
/// call (the monomorphized inherent solver paths remain for hot loops).
#[derive(Debug, Clone, Copy)]
pub enum DynLoss {
    /// Hinge loss.
    Hinge(Hinge),
    /// Squared hinge.
    SquaredHinge(SquaredHinge),
    /// Logistic loss.
    Logistic(Logistic),
    /// Square loss.
    Square(Square),
}

macro_rules! dispatch_loss {
    ($self:expr, $l:ident => $e:expr) => {
        match $self {
            DynLoss::Hinge($l) => $e,
            DynLoss::SquaredHinge($l) => $e,
            DynLoss::Logistic($l) => $e,
            DynLoss::Square($l) => $e,
        }
    };
}

impl DynLoss {
    /// Build the concrete loss for `kind` with penalty `c > 0`.
    pub fn new(kind: LossKind, c: f64) -> DynLoss {
        match kind {
            LossKind::Hinge => DynLoss::Hinge(Hinge::new(c)),
            LossKind::SquaredHinge => {
                DynLoss::SquaredHinge(SquaredHinge::new(c))
            }
            LossKind::Logistic => DynLoss::Logistic(Logistic::new(c)),
            LossKind::Square => DynLoss::Square(Square::new(c)),
        }
    }

    /// The kind this loss dispatches to.
    pub fn kind(&self) -> LossKind {
        match self {
            DynLoss::Hinge(_) => LossKind::Hinge,
            DynLoss::SquaredHinge(_) => LossKind::SquaredHinge,
            DynLoss::Logistic(_) => LossKind::Logistic,
            DynLoss::Square(_) => LossKind::Square,
        }
    }

    /// The penalty parameter `C` it was built with.
    pub fn c(&self) -> f64 {
        dispatch_loss!(self, l => l.c)
    }
}

impl Loss for DynLoss {
    fn name(&self) -> &'static str {
        dispatch_loss!(self, l => l.name())
    }

    #[inline]
    fn primal(&self, z: f64) -> f64 {
        dispatch_loss!(self, l => l.primal(z))
    }

    #[inline]
    fn conjugate_neg(&self, alpha: f64) -> f64 {
        dispatch_loss!(self, l => l.conjugate_neg(alpha))
    }

    #[inline]
    fn project(&self, alpha: f64) -> f64 {
        dispatch_loss!(self, l => l.project(alpha))
    }

    #[inline]
    fn solve_subproblem(&self, alpha: f64, wx: f64, q: f64) -> f64 {
        dispatch_loss!(self, l => l.solve_subproblem(alpha, wx, q))
    }

    #[inline]
    fn dual_gradient(&self, alpha: f64, wx: f64) -> f64 {
        dispatch_loss!(self, l => l.dual_gradient(alpha, wx))
    }

    fn upper_bound(&self) -> Option<f64> {
        dispatch_loss!(self, l => l.upper_bound())
    }
}

/// A loss with everything the solvers need.  Implementations are
/// zero-sized-plus-C structs; solver loops are monomorphized over them.
pub trait Loss: Copy + Send + Sync + 'static {
    /// Short identifier for configs/logs.
    fn name(&self) -> &'static str;

    /// Primal loss `ℓ(z)` at margin `z = w·x_i`.
    fn primal(&self, z: f64) -> f64;

    /// Conjugate value `ℓ*(−α)`.  Callers guarantee `α` feasible
    /// (see [`Loss::project`]); the dual objective sums this.
    fn conjugate_neg(&self, alpha: f64) -> f64;

    /// Project `α` onto the conjugate's domain (e.g. `[0, C]`).
    fn project(&self, alpha: f64) -> f64;

    /// Solve the one-variable subproblem: given the current `α_i`, the
    /// margin `wx = w·x_i`, and `q = ‖x_i‖² > 0`, return the *new* α_i.
    fn solve_subproblem(&self, alpha: f64, wx: f64, q: f64) -> f64;

    /// Gradient of the dual coordinate (for shrinking heuristics):
    /// `∇_i D(α) = w·x_i + (ℓ*)'(−α_i)·(−1)` — for hinge this is
    /// `w·x_i − 1`.  Default implementation via the subproblem is not
    /// possible, so each loss provides it.
    fn dual_gradient(&self, alpha: f64, wx: f64) -> f64;

    /// Upper bound of the feasible dual box if finite (`Some(C)` for
    /// hinge), used by the shrinking heuristic.
    fn upper_bound(&self) -> Option<f64>;
}

/// Numerical safety: treat |δ| below this as a no-op update.
pub const MIN_DELTA: f64 = 1e-16;

#[cfg(test)]
pub(crate) mod testutil {
    use super::Loss;

    /// Brute-force the subproblem minimizer by golden-section search over
    /// the feasible interval — validates the closed-form/Newton solvers.
    pub fn brute_force_subproblem<L: Loss>(
        loss: &L,
        alpha: f64,
        wx: f64,
        q: f64,
        lo: f64,
        hi: f64,
    ) -> f64 {
        let obj = |a: f64| {
            let delta = a - alpha;
            0.5 * q * delta * delta + wx * delta + loss.conjugate_neg(a)
        };
        let phi = (5.0_f64.sqrt() - 1.0) / 2.0;
        let (mut a, mut b) = (lo, hi);
        for _ in 0..200 {
            let c = b - phi * (b - a);
            let d = a + phi * (b - a);
            if obj(c) < obj(d) {
                b = d;
            } else {
                a = c;
            }
        }
        0.5 * (a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_kind_roundtrip_and_aliases() {
        for kind in LossKind::ALL {
            assert_eq!(LossKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(LossKind::parse("l2svm").unwrap(), LossKind::SquaredHinge);
        assert_eq!(LossKind::parse("logreg").unwrap(), LossKind::Logistic);
        assert_eq!(LossKind::parse("ridge").unwrap(), LossKind::Square);
        let err = format!("{:#}", LossKind::parse("huber").unwrap_err());
        assert!(err.contains("hinge") && err.contains("logistic"), "{err}");
    }

    #[test]
    fn dyn_loss_matches_concrete_loss() {
        let c = 1.5;
        let h = Hinge::new(c);
        let d = DynLoss::new(LossKind::Hinge, c);
        assert_eq!(d.kind(), LossKind::Hinge);
        assert_eq!(d.c(), c);
        assert_eq!(d.name(), "hinge");
        for &(a, wx, q) in &[(0.0, -0.5, 1.0), (0.7, 2.0, 0.3), (1.5, 1.0, 2.0)] {
            assert_eq!(d.solve_subproblem(a, wx, q), h.solve_subproblem(a, wx, q));
            assert_eq!(d.dual_gradient(a, wx), h.dual_gradient(a, wx));
            assert_eq!(d.project(a), h.project(a));
            assert_eq!(d.primal(wx), h.primal(wx));
        }
        assert_eq!(d.upper_bound(), h.upper_bound());

        let lg = Logistic::new(c);
        let dl = DynLoss::new(LossKind::Logistic, c);
        let a = dl.project(0.3 * c);
        assert_eq!(dl.solve_subproblem(a, 0.4, 1.2), lg.solve_subproblem(a, 0.4, 1.2));
    }
}
