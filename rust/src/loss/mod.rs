//! Loss library: primal losses, their conjugates, and the closed-form /
//! Newton solvers for the one-variable dual subproblem
//!
//! ```text
//!   Δα_i = argmin_δ  ½‖w + δ x_i‖² + ℓ*_i(−(α_i + δ))            (paper Eq. 4)
//! ```
//!
//! which, expanding the quadratic and dropping constants, is
//!
//! ```text
//!   argmin_δ  ½ q δ² + (w·x_i) δ + ℓ*_i(−(α_i + δ)),   q = ‖x_i‖².
//! ```
//!
//! Rows are label-folded (`x_i = y_i ẋ_i`), so every loss is a function of
//! the margin `z = w·x_i` and the dual variable lives in the conjugate's
//! domain (e.g. `[0, C]` for hinge).

pub mod hinge;
pub mod logistic;
pub mod square;
pub mod squared_hinge;

pub use hinge::Hinge;
pub use logistic::Logistic;
pub use square::Square;
pub use squared_hinge::SquaredHinge;

/// A loss with everything the solvers need.  Implementations are
/// zero-sized-plus-C structs; solver loops are monomorphized over them.
pub trait Loss: Copy + Send + Sync + 'static {
    /// Short identifier for configs/logs.
    fn name(&self) -> &'static str;

    /// Primal loss `ℓ(z)` at margin `z = w·x_i`.
    fn primal(&self, z: f64) -> f64;

    /// Conjugate value `ℓ*(−α)`.  Callers guarantee `α` feasible
    /// (see [`Loss::project`]); the dual objective sums this.
    fn conjugate_neg(&self, alpha: f64) -> f64;

    /// Project `α` onto the conjugate's domain (e.g. `[0, C]`).
    fn project(&self, alpha: f64) -> f64;

    /// Solve the one-variable subproblem: given the current `α_i`, the
    /// margin `wx = w·x_i`, and `q = ‖x_i‖² > 0`, return the *new* α_i.
    fn solve_subproblem(&self, alpha: f64, wx: f64, q: f64) -> f64;

    /// Gradient of the dual coordinate (for shrinking heuristics):
    /// `∇_i D(α) = w·x_i + (ℓ*)'(−α_i)·(−1)` — for hinge this is
    /// `w·x_i − 1`.  Default implementation via the subproblem is not
    /// possible, so each loss provides it.
    fn dual_gradient(&self, alpha: f64, wx: f64) -> f64;

    /// Upper bound of the feasible dual box if finite (`Some(C)` for
    /// hinge), used by the shrinking heuristic.
    fn upper_bound(&self) -> Option<f64>;
}

/// Numerical safety: treat |δ| below this as a no-op update.
pub const MIN_DELTA: f64 = 1e-16;

#[cfg(test)]
pub(crate) mod testutil {
    use super::Loss;

    /// Brute-force the subproblem minimizer by golden-section search over
    /// the feasible interval — validates the closed-form/Newton solvers.
    pub fn brute_force_subproblem<L: Loss>(
        loss: &L,
        alpha: f64,
        wx: f64,
        q: f64,
        lo: f64,
        hi: f64,
    ) -> f64 {
        let obj = |a: f64| {
            let delta = a - alpha;
            0.5 * q * delta * delta + wx * delta + loss.conjugate_neg(a)
        };
        let phi = (5.0_f64.sqrt() - 1.0) / 2.0;
        let (mut a, mut b) = (lo, hi);
        for _ in 0..200 {
            let c = b - phi * (b - a);
            let d = a + phi * (b - a);
            if obj(c) < obj(d) {
                b = d;
            } else {
                a = c;
            }
        }
        0.5 * (a + b)
    }
}
