//! Hinge loss (L1-SVM), the paper's experimental workhorse.
//!
//! ```text
//!   ℓ(z)      = C · max(0, 1 − z)
//!   ℓ*(−α)    = −α          for α ∈ [0, C],  +∞ otherwise     (paper Eq. 10)
//!   update    α ← Π_[0,C]( α − (w·x_i − 1) / ‖x_i‖² )
//! ```

use super::Loss;

/// Hinge loss with penalty parameter `C`.
#[derive(Debug, Clone, Copy)]
pub struct Hinge {
    pub c: f64,
}

impl Hinge {
    pub fn new(c: f64) -> Self {
        assert!(c > 0.0);
        Self { c }
    }
}

impl Loss for Hinge {
    fn name(&self) -> &'static str {
        "hinge"
    }

    #[inline]
    fn primal(&self, z: f64) -> f64 {
        self.c * (1.0 - z).max(0.0)
    }

    #[inline]
    fn conjugate_neg(&self, alpha: f64) -> f64 {
        debug_assert!(
            (-1e-9..=self.c + 1e-9).contains(&alpha),
            "alpha {alpha} outside [0, {}]",
            self.c
        );
        -alpha
    }

    #[inline]
    fn project(&self, alpha: f64) -> f64 {
        alpha.clamp(0.0, self.c)
    }

    #[inline]
    fn solve_subproblem(&self, alpha: f64, wx: f64, q: f64) -> f64 {
        debug_assert!(q > 0.0);
        (alpha - (wx - 1.0) / q).clamp(0.0, self.c)
    }

    #[inline]
    fn dual_gradient(&self, _alpha: f64, wx: f64) -> f64 {
        wx - 1.0
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(self.c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::brute_force_subproblem;

    #[test]
    fn primal_values() {
        let h = Hinge::new(2.0);
        assert_eq!(h.primal(2.0), 0.0);
        assert_eq!(h.primal(1.0), 0.0);
        assert_eq!(h.primal(0.0), 2.0);
        assert_eq!(h.primal(-1.0), 4.0);
    }

    #[test]
    fn projection_clamps() {
        let h = Hinge::new(1.0);
        assert_eq!(h.project(-0.5), 0.0);
        assert_eq!(h.project(0.5), 0.5);
        assert_eq!(h.project(1.5), 1.0);
    }

    #[test]
    fn subproblem_matches_brute_force() {
        let h = Hinge::new(0.75);
        for &(alpha, wx, q) in &[
            (0.0, -0.5, 1.0),
            (0.2, 0.3, 0.5),
            (0.75, 2.0, 2.0),
            (0.4, 1.0, 0.1),
            (0.0, 5.0, 1.0),
        ] {
            let got = h.solve_subproblem(alpha, wx, q);
            let want = brute_force_subproblem(&h, alpha, wx, q, 0.0, h.c);
            assert!(
                (got - want).abs() < 1e-6,
                "alpha={alpha} wx={wx} q={q}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn subproblem_fixed_point_at_optimum() {
        // At the unconstrained optimum wx = 1, alpha should not move.
        let h = Hinge::new(1.0);
        assert_eq!(h.solve_subproblem(0.3, 1.0, 0.8), 0.3);
    }

    #[test]
    fn gradient_sign() {
        let h = Hinge::new(1.0);
        assert!(h.dual_gradient(0.0, 2.0) > 0.0); // margin > 1: push α down
        assert!(h.dual_gradient(0.0, 0.0) < 0.0); // violated: push α up
    }
}
