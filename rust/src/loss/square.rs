//! Square loss (LS-SVM / ridge regression on folded labels) — the
//! "ridge regression" member of the paper's problem family (§1).
//!
//! ```text
//!   ℓ(z)   = C · (1 − z)²
//!   ℓ*(−α) = −α + α²/(4C)            (unconstrained: α ∈ ℝ)
//! ```
//!
//! Identical conjugate algebra to the squared hinge but with no
//! nonnegativity constraint, so the subproblem is an unconstrained
//! quadratic with closed form
//!
//! ```text
//!   α ← α − (wx − 1 + α/(2C)) / (q + 1/(2C)).
//! ```

use super::Loss;

/// Square loss with penalty parameter `C`.
#[derive(Debug, Clone, Copy)]
pub struct Square {
    pub c: f64,
}

impl Square {
    pub fn new(c: f64) -> Self {
        assert!(c > 0.0);
        Self { c }
    }
}

impl Loss for Square {
    fn name(&self) -> &'static str {
        "square"
    }

    #[inline]
    fn primal(&self, z: f64) -> f64 {
        let r = 1.0 - z;
        self.c * r * r
    }

    #[inline]
    fn conjugate_neg(&self, alpha: f64) -> f64 {
        -alpha + alpha * alpha / (4.0 * self.c)
    }

    #[inline]
    fn project(&self, alpha: f64) -> f64 {
        alpha // unconstrained
    }

    #[inline]
    fn solve_subproblem(&self, alpha: f64, wx: f64, q: f64) -> f64 {
        debug_assert!(q > 0.0);
        let inv2c = 1.0 / (2.0 * self.c);
        alpha - (wx - 1.0 + alpha * inv2c) / (q + inv2c)
    }

    #[inline]
    fn dual_gradient(&self, alpha: f64, wx: f64) -> f64 {
        wx - 1.0 + alpha / (2.0 * self.c)
    }

    fn upper_bound(&self) -> Option<f64> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::brute_force_subproblem;

    #[test]
    fn primal_values() {
        let l = Square::new(2.0);
        assert_eq!(l.primal(1.0), 0.0);
        assert_eq!(l.primal(0.0), 2.0);
        assert_eq!(l.primal(3.0), 8.0);
    }

    #[test]
    fn subproblem_matches_brute_force_including_negative_alpha() {
        let l = Square::new(1.5);
        for &(alpha, wx, q) in &[
            (0.0, -0.5, 1.0),
            (-0.8, 0.3, 0.5),
            (2.0, 2.0, 2.0),
            (0.4, -3.0, 0.1),
        ] {
            let got = l.solve_subproblem(alpha, wx, q);
            let want = brute_force_subproblem(&l, alpha, wx, q, -20.0, 20.0);
            assert!(
                (got - want).abs() < 1e-5,
                "alpha={alpha} wx={wx} q={q}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn subproblem_is_exactly_stationary() {
        let l = Square::new(0.7);
        let (alpha, wx, q) = (0.3, -0.9, 1.3);
        let a = l.solve_subproblem(alpha, wx, q);
        let g = q * (a - alpha) + wx - 1.0 + a / (2.0 * l.c);
        assert!(g.abs() < 1e-12, "residual {g}");
    }

    #[test]
    fn dcd_converges_on_ridge_problem() {
        use crate::data::registry;
        use crate::eval;
        use crate::solver::{SerialDcd, SolveOptions};
        let (ds, _, _) = registry::load("rcv1", 0.02).unwrap();
        let l = Square::new(0.5);
        let r = SerialDcd::solve(
            &ds,
            &l,
            &SolveOptions { epochs: 30, ..Default::default() },
            None,
        );
        let gap = eval::duality_gap(&ds, &l, &r.alpha);
        let p = eval::primal_objective(&ds, &l, &r.w_hat);
        assert!(gap < 1e-3 * p.abs().max(1.0), "gap {gap} (P={p})");
    }
}
