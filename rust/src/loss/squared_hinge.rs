//! Squared hinge loss (L2-SVM).
//!
//! ```text
//!   ℓ(z)   = C · max(0, 1 − z)²
//!   ℓ*(−α) = −α + α²/(4C)    for α ≥ 0,  +∞ otherwise          (paper Eq. 11)
//! ```
//!
//! The subproblem objective `½qδ² + wx·δ + (−(α+δ) + (α+δ)²/(4C))` is a
//! smooth quadratic in δ on `α+δ ≥ 0`; its unconstrained minimizer is
//!
//! ```text
//!   δ = −(wx − 1 + α/(2C)) / (q + 1/(2C)),
//! ```
//!
//! projected onto `α + δ ≥ 0`.

use super::Loss;

/// Squared hinge loss with penalty parameter `C`.
#[derive(Debug, Clone, Copy)]
pub struct SquaredHinge {
    pub c: f64,
}

impl SquaredHinge {
    pub fn new(c: f64) -> Self {
        assert!(c > 0.0);
        Self { c }
    }
}

impl Loss for SquaredHinge {
    fn name(&self) -> &'static str {
        "squared_hinge"
    }

    #[inline]
    fn primal(&self, z: f64) -> f64 {
        let h = (1.0 - z).max(0.0);
        self.c * h * h
    }

    #[inline]
    fn conjugate_neg(&self, alpha: f64) -> f64 {
        debug_assert!(alpha >= -1e-9, "alpha {alpha} < 0");
        -alpha + alpha * alpha / (4.0 * self.c)
    }

    #[inline]
    fn project(&self, alpha: f64) -> f64 {
        alpha.max(0.0)
    }

    #[inline]
    fn solve_subproblem(&self, alpha: f64, wx: f64, q: f64) -> f64 {
        debug_assert!(q > 0.0);
        let inv2c = 1.0 / (2.0 * self.c);
        let delta = -(wx - 1.0 + alpha * inv2c) / (q + inv2c);
        (alpha + delta).max(0.0)
    }

    #[inline]
    fn dual_gradient(&self, alpha: f64, wx: f64) -> f64 {
        wx - 1.0 + alpha / (2.0 * self.c)
    }

    fn upper_bound(&self) -> Option<f64> {
        None // α is only lower-bounded for L2-SVM
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::brute_force_subproblem;

    #[test]
    fn primal_values() {
        let l = SquaredHinge::new(1.0);
        assert_eq!(l.primal(1.0), 0.0);
        assert_eq!(l.primal(0.0), 1.0);
        assert_eq!(l.primal(-1.0), 4.0);
        assert_eq!(l.primal(3.0), 0.0);
    }

    #[test]
    fn conjugate_matches_paper_formula() {
        let l = SquaredHinge::new(0.5);
        // ℓ*(−α) = −α + α²/(4C) = −1 + 1/2 at α = 1, C = 0.5
        assert!((l.conjugate_neg(1.0) - (-0.5)).abs() < 1e-12);
        assert_eq!(l.conjugate_neg(0.0), 0.0);
    }

    #[test]
    fn subproblem_matches_brute_force() {
        let l = SquaredHinge::new(2.0);
        for &(alpha, wx, q) in &[
            (0.0, -0.5, 1.0),
            (1.2, 0.3, 0.5),
            (3.0, 2.0, 2.0),
            (0.4, 1.0, 0.1),
            (0.0, 5.0, 1.0),
        ] {
            let got = l.solve_subproblem(alpha, wx, q);
            // feasible interval is α ≥ 0 — bracket generously
            let want = brute_force_subproblem(&l, alpha, wx, q, 0.0, 20.0);
            assert!(
                (got - want).abs() < 1e-5,
                "alpha={alpha} wx={wx} q={q}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn stationarity_of_interior_solution() {
        // If the new α is interior (> 0), the subproblem gradient there
        // must vanish: q·δ + wx + d/dα ℓ*(−α_new) = 0.
        let l = SquaredHinge::new(1.5);
        let (alpha, wx, q) = (0.7, 0.2, 0.9);
        let a_new = l.solve_subproblem(alpha, wx, q);
        assert!(a_new > 0.0);
        let delta = a_new - alpha;
        let grad = q * delta + wx - 1.0 + a_new / (2.0 * l.c);
        assert!(grad.abs() < 1e-10, "gradient {grad}");
    }
}
