//! Logistic loss (ℓ2-regularized logistic regression).
//!
//! ```text
//!   ℓ(z)   = C · log(1 + e^(−z))
//!   ℓ*(−α) = α·log(α) + (C−α)·log(C−α) − C·log(C)   for α ∈ (0, C)
//! ```
//!
//! The one-variable subproblem has no closed form (paper §3.1 cites
//! Yu et al. 2012); we solve the stationarity condition
//!
//! ```text
//!   g(a) = q·(a − α) + wx + log(a / (C − a)) = 0,   a ∈ (0, C)
//! ```
//!
//! by safeguarded Newton (bisection fallback), 1e-12 tolerance.

use super::Loss;

/// Logistic loss with penalty parameter `C`.
#[derive(Debug, Clone, Copy)]
pub struct Logistic {
    pub c: f64,
}

impl Logistic {
    pub fn new(c: f64) -> Self {
        assert!(c > 0.0);
        Self { c }
    }

    /// Margin of feasibility: α is kept in [eps, C − eps].
    #[inline]
    fn eps(&self) -> f64 {
        1e-12 * self.c
    }
}

impl Loss for Logistic {
    fn name(&self) -> &'static str {
        "logistic"
    }

    #[inline]
    fn primal(&self, z: f64) -> f64 {
        // log(1 + e^-z), numerically stable both directions
        self.c
            * if z > 0.0 {
                (-z).exp().ln_1p()
            } else {
                -z + z.exp().ln_1p()
            }
    }

    #[inline]
    fn conjugate_neg(&self, alpha: f64) -> f64 {
        let c = self.c;
        let a = alpha.clamp(self.eps(), c - self.eps());
        a * a.ln() + (c - a) * (c - a).ln() - c * c.ln()
    }

    #[inline]
    fn project(&self, alpha: f64) -> f64 {
        alpha.clamp(self.eps(), self.c - self.eps())
    }

    fn solve_subproblem(&self, alpha: f64, wx: f64, q: f64) -> f64 {
        debug_assert!(q > 0.0);
        let c = self.c;
        let eps = self.eps();
        let alpha = alpha.clamp(eps, c - eps);
        // g(a) = q (a − α) + wx + ln(a / (C − a)); strictly increasing.
        let g = |a: f64| q * (a - alpha) + wx + (a / (c - a)).ln();
        let (mut lo, mut hi) = (eps, c - eps);
        if g(lo) >= 0.0 {
            return lo;
        }
        if g(hi) <= 0.0 {
            return hi;
        }
        let mut a = alpha.clamp(lo, hi);
        for _ in 0..100 {
            let ga = g(a);
            if ga.abs() < 1e-12 {
                break;
            }
            if ga > 0.0 {
                hi = a;
            } else {
                lo = a;
            }
            // Newton step; g'(a) = q + C / (a (C − a))
            let gp = q + c / (a * (c - a));
            let mut next = a - ga / gp;
            if !(next > lo && next < hi) {
                next = 0.5 * (lo + hi); // bisection safeguard
            }
            if (next - a).abs() < 1e-15 {
                a = next;
                break;
            }
            a = next;
        }
        a
    }

    #[inline]
    fn dual_gradient(&self, alpha: f64, wx: f64) -> f64 {
        let a = self.project(alpha);
        wx + (a / (self.c - a)).ln()
    }

    fn upper_bound(&self) -> Option<f64> {
        Some(self.c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::brute_force_subproblem;

    #[test]
    fn primal_is_stable_at_extremes() {
        let l = Logistic::new(1.0);
        assert!(l.primal(100.0) < 1e-40);
        assert!((l.primal(-100.0) - 100.0).abs() < 1e-9);
        assert!((l.primal(0.0) - (2.0_f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn conjugate_symmetric_minimum_at_half_c() {
        let l = Logistic::new(2.0);
        // ℓ*(−α) is minimized at α = C/2 with value −C·log 2
        let min = l.conjugate_neg(1.0);
        assert!((min - (-2.0 * (2.0_f64).ln())).abs() < 1e-9);
        assert!(l.conjugate_neg(0.5) > min);
        assert!(l.conjugate_neg(1.5) > min);
    }

    #[test]
    fn subproblem_matches_brute_force() {
        let l = Logistic::new(1.0);
        for &(alpha, wx, q) in &[
            (0.5, -0.5, 1.0),
            (0.1, 0.3, 0.5),
            (0.9, 2.0, 2.0),
            (0.5, 0.0, 0.1),
            (0.01, -3.0, 1.0),
        ] {
            let got = l.solve_subproblem(alpha, wx, q);
            let want =
                brute_force_subproblem(&l, alpha, wx, q, 1e-9, 1.0 - 1e-9);
            assert!(
                (got - want).abs() < 1e-5,
                "alpha={alpha} wx={wx} q={q}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn solution_is_stationary() {
        let l = Logistic::new(3.0);
        let (alpha, wx, q) = (1.0, 0.4, 0.7);
        let a = l.solve_subproblem(alpha, wx, q);
        let g = q * (a - alpha) + wx + (a / (l.c - a)).ln();
        assert!(g.abs() < 1e-9, "stationarity residual {g}");
    }

    #[test]
    fn strongly_pushed_solution_saturates() {
        let l = Logistic::new(1.0);
        // Huge positive margin pushes α towards 0; huge negative towards C.
        assert!(l.solve_subproblem(0.5, 50.0, 1.0) < 1e-6);
        assert!(l.solve_subproblem(0.5, -50.0, 1.0) > 1.0 - 1e-6);
    }
}
