//! HTTP serving end to end: train two models, serve them on separate
//! routes over a real loopback socket, score through the wire, check
//! the stats plane, and hot-swap one route without touching the other.
//!
//! Run: `cargo run --release --example http_serving`

use passcode::coordinator::config::RunConfig;
use passcode::coordinator::driver;
use passcode::data::registry as data_registry;
use passcode::net::{HttpClient, Router, RoutesConfig, Server, ServerConfig};

fn main() -> anyhow::Result<()> {
    // ---- train one model per route (different datasets) -------------
    let dir = std::env::temp_dir().join("passcode_http_example");
    std::fs::create_dir_all(&dir)?;
    let mut paths = Vec::new();
    for dataset in ["rcv1", "news20"] {
        let cfg = RunConfig {
            dataset: dataset.into(),
            scale: 0.02,
            epochs: 5,
            threads: 2,
            eval_every: 0,
            ..Default::default()
        };
        let (model, _) = driver::train_model(&cfg)?;
        let path = dir.join(format!("{dataset}.json"));
        model.save(&path)?;
        println!("trained {dataset} model -> {}", path.display());
        paths.push(path);
    }

    // ---- one route per model, one engine per route ------------------
    let routes = RoutesConfig::from_json_text(&format!(
        r#"{{"routes": [
            {{"name": "rcv1", "model": {:?}, "shards": 2}},
            {{"name": "news20", "model": {:?}, "shards": 2}}
        ]}}"#,
        paths[0].to_str().unwrap(),
        paths[1].to_str().unwrap(),
    ))?;
    let server = Server::start(
        Router::start(&routes)?,
        &ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, ..Default::default() },
    )?;
    println!("listening on http://{}\n", server.addr());

    let mut client = HttpClient::new(server.addr());

    // ---- health + stats ---------------------------------------------
    let health = client.get("/healthz")?.ok()?.json()?;
    println!("GET /healthz -> {health}");

    // ---- score a held-out row on each route over the wire -----------
    for route in ["rcv1", "news20"] {
        let (_, test, _) = data_registry::load(route, 0.02)?;
        let row = test.raw_row(0);
        let resp = client.score(route, &row)?.ok()?.json()?;
        let p = &resp.get("predictions")?.as_arr()?[0];
        println!(
            "POST /v1/score?route={route} -> margin {:+.4} label {:+.0} (epoch {})",
            p.get("margin")?.as_f64()?,
            p.get("label")?.as_f64()?,
            p.get("model_epoch")?.as_usize()?,
        );
    }

    // ---- hot-swap route rcv1; news20 is untouched -------------------
    let publish = format!("{{\"path\": {:?}}}", paths[0].to_str().unwrap());
    let resp = client
        .request("POST", "/v1/models/rcv1/publish", "application/json", publish.as_bytes())?
        .ok()?
        .json()?;
    println!("\nPOST /v1/models/rcv1/publish -> epoch {}", resp.get("epoch")?.as_usize()?);
    let stats = client.get("/v1/stats")?.ok()?.json()?;
    for route in ["rcv1", "news20"] {
        let r = stats.get("routes")?.get(route)?;
        println!(
            "  {route}: epoch {} versions_alive {} requests {}",
            r.get("epoch")?.as_usize()?,
            r.get("versions_alive")?.as_usize()?,
            r.get("requests")?.as_usize()?,
        );
    }

    // ---- wind down ---------------------------------------------------
    println!();
    for (name, report) in server.shutdown() {
        println!("route {name} final:\n{}", report.render());
    }
    Ok(())
}
