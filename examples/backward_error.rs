//! Empirical backward-error analysis (paper §4.2, Theorem 3).
//!
//! Runs PASSCoDe-Wild on the multicore simulator (real races cannot occur
//! on this 1-core host — DESIGN.md §3), measures ε = w̄ − ŵ (the lost-
//! write error), and verifies Theorem 3's claim: ŵ satisfies the
//! optimality conditions of the *perturbed* primal problem, which is why
//! Table 2 predicts with ŵ.
//!
//! ```text
//! cargo run --release --example backward_error
//! ```

use passcode::coordinator::experiments;

fn main() -> anyhow::Result<()> {
    println!("=== PASSCoDe-Wild backward error (Theorem 3, simulated cores) ===\n");
    println!(
        "dataset   cores   lost writes   ‖ε‖/‖ŵ‖     KKT resid(ŵ)   KKT resid(w̄)"
    );
    for dataset in ["rcv1", "news20", "webspam"] {
        for cores in [2usize, 8, 16] {
            let be = experiments::backward_error(dataset, 0.05, 20, cores)?;
            println!(
                "{dataset:<9} {cores:>5}   {:>11}   {:>9.3e}   {:>12.3e}   {:>12.3e}",
                be.lost_writes,
                be.eps_norm / be.w_norm.max(1e-12),
                be.perturbed_residual,
                be.unperturbed_residual,
            );
        }
    }
    println!(
        "\nReading: lost writes (and hence ε) grow with core count, yet\n\
         ε stays small relative to ŵ and the KKT residual measured with\n\
         ŵ stays comparable to the w̄ one — the Wild iterate is the exact\n\
         solution of a nearby perturbed problem, so predict with ŵ\n\
         (paper Table 2, §4.2)."
    );
    Ok(())
}
