//! Serving walkthrough: train a model, stand up the online scoring
//! stack (versioned registry + microbatcher + sharded scorers), stream
//! held-out traffic through it, and keep learning while serving via the
//! async continuous trainer — PASSCoDe-Wild warm-started from the live
//! `(α, ŵ)` and hot-swapped in with zero reader blocking (Theorem 3's
//! license).
//!
//! ```text
//! cargo run --release --example serving
//! ```

use std::sync::Arc;
use std::time::Duration;

use passcode::coordinator::{driver, RunConfig, SolverKind};
use passcode::data::registry;
use passcode::loss::LossKind;
use passcode::serve::{OnlineConfig, OnlineTrainer, ServeConfig, ServeEngine};
use passcode::solver::MemoryModel;

fn main() -> anyhow::Result<()> {
    // ---- 1: offline training, exactly as `passcode train` -----------
    let cfg = RunConfig {
        dataset: "rcv1".into(),
        scale: 0.1,
        solver: SolverKind::Passcode(MemoryModel::Wild),
        threads: 2,
        epochs: 10,
        eval_every: 0,
        ..Default::default()
    };
    println!("training the initial model ({} @ {})...", cfg.dataset, cfg.scale);
    let (model, result) = driver::train_model(&cfg)?;
    let (_, test, c) = registry::load(&cfg.dataset, cfg.scale)?;
    println!(
        "  trained: d = {}, {} updates in {:.3}s",
        model.w.len(),
        result.updates,
        result.train_secs()
    );

    // ---- 2: bring up the serving engine ------------------------------
    let serve_cfg = ServeConfig {
        shards: 4,
        max_batch: 64,
        max_wait: Duration::from_micros(200),
        pin_threads: false,
    };
    let engine = ServeEngine::start(model, Some(result.alpha), &serve_cfg);
    println!(
        "serving on {} shards, microbatch ≤ {} with {:?} budget",
        serve_cfg.shards, serve_cfg.max_batch, serve_cfg.max_wait
    );

    // ---- 3: continuous training against the live registry -----------
    let trainer = Arc::new(OnlineTrainer::new(
        Arc::clone(engine.registry()),
        LossKind::Hinge,
        c,
        OnlineConfig {
            epochs_per_round: 2,
            threads: 2,
            max_window: test.n().max(1),
            seed: 7,
            ..Default::default()
        },
    ));

    // ---- 4: replay the held-out split as traffic ---------------------
    // Each scored row's label then "arrives" and feeds the trainer;
    // every quarter of the stream we run a training round, which
    // hot-swaps a fresher model under the scorers mid-flight.
    let n = test.n();
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        let y = test.y[i];
        let (idx, raw) = test.raw_row(i); // unfold x = y·ẋ
        tickets.push((engine.submit(idx.clone(), raw.clone()), y));
        trainer.ingest(idx, raw, y);
        if n >= 4 && (i + 1) % (n / 4) == 0 && i + 1 < n {
            if let Some(epoch) = trainer.train_round() {
                println!(
                    "  hot-swapped model epoch {epoch} at request {}/{n}",
                    i + 1
                );
            }
        }
    }

    let mut correct = 0usize;
    let (mut emin, mut emax) = (u64::MAX, 0u64);
    for (t, y) in tickets {
        let p = t.wait();
        if p.label == y {
            correct += 1;
        }
        emin = emin.min(p.model_epoch);
        emax = emax.max(p.model_epoch);
    }
    println!(
        "served {} requests, accuracy {:.4}, scored by model epochs {emin}..={emax}",
        n,
        correct as f64 / n.max(1) as f64
    );

    // ---- 5: shut down and report -------------------------------------
    let report = engine.shutdown();
    print!("{}", report.render());
    println!(
        "registry kept {} versions; no request waited on a swap (reads \
         are wait-free)",
        trainer.rounds() + 1
    );
    Ok(())
}
