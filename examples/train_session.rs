//! The unified solver API end to end: look every solver up in the
//! registry, drive it through a resumable `TrainSession`, checkpoint to
//! disk mid-run, restore into a fresh session, and finish under a
//! deadline — the controls the serving-side online trainer runs on.
//!
//! ```text
//! cargo run --release --example train_session
//! ```

use std::time::{Duration, Instant};

use passcode::coordinator::model_io::{load_checkpoint, save_checkpoint};
use passcode::data::registry;
use passcode::eval;
use passcode::loss::LossKind;
use passcode::solver::{lookup, solver_names, Solver, SolveOptions, StopWhen};

fn main() -> anyhow::Result<()> {
    let (train, test, c) = registry::load("rcv1", 0.05)?;
    println!("=== TrainSession walkthrough (rcv1 analog, C = {c}) ===\n");

    // ---- 1: every registry solver through the same loop --------------
    println!("{:<16} {:>8} {:>12} {:>10}", "solver", "epochs", "gap", "acc");
    for name in solver_names() {
        let solver = lookup(name)?;
        let opts = SolveOptions { threads: 2, epochs: 8, ..Default::default() };
        let mut session = match solver.session(&train, LossKind::Hinge, c, opts)
        {
            Ok(s) => s,
            Err(e) => {
                // AsySCD's dense-Q guard fires here at full scale, just
                // like the paper's 256 GB machine: report and move on.
                println!("{name:<16} skipped: {e:#}");
                continue;
            }
        };
        session.run_epochs(8)?;
        println!(
            "{:<16} {:>8} {:>12.4e} {:>10.4}",
            name,
            session.epochs(),
            session.duality_gap(),
            eval::accuracy(&test, session.w_hat()),
        );
    }

    // ---- 2: checkpoint/restore round trip -----------------------------
    let solver = lookup("passcode-wild")?;
    let opts = SolveOptions { threads: 2, epochs: 10, ..Default::default() };
    let mut first =
        solver.session(&train, LossKind::Hinge, c, opts.clone())?;
    first.run_epochs(5)?;
    let path = std::env::temp_dir().join("train_session_ckpt.json");
    save_checkpoint(&first.snapshot(), &path)?;
    println!("\ncheckpointed after {} epochs -> {}", first.epochs(), path.display());

    let ckpt = load_checkpoint(&path)?;
    let mut second = solver.session(&train, LossKind::Hinge, c, opts)?;
    second.resume(&ckpt)?;
    // ---- 3: finish under a wall-clock deadline ------------------------
    let report = second
        .run_until(StopWhen::Deadline(Instant::now() + Duration::from_secs(5)))?;
    println!(
        "resumed at epoch {} and ran {} more ({:?}); final acc {:.4}",
        ckpt.epochs_done,
        report.epochs_run,
        report.stopped,
        eval::accuracy(&test, second.w_hat()),
    );
    println!("\ntrain_session OK");
    Ok(())
}
