//! ℓ2-regularized logistic regression via dual coordinate descent — the
//! paper's "other losses" claim (§3.1: the subproblem needs an iterative
//! inner solver; we use safeguarded Newton, `loss/logistic.rs`).
//!
//! Compares serial DCD and PASSCoDe-Wild on the news20 analog, for both
//! logistic and squared-hinge losses.
//!
//! ```text
//! cargo run --release --example logistic_regression
//! ```

use passcode::coordinator::{driver, LossKind, RunConfig, SolverKind};
use passcode::solver::MemoryModel;

fn main() -> anyhow::Result<()> {
    println!("=== DCD beyond hinge: logistic / squared hinge / square (ridge) ===\n");
    for loss in [LossKind::Logistic, LossKind::SquaredHinge, LossKind::Square] {
        println!("--- loss = {} ---", loss.name());
        for (label, solver, threads) in [
            ("dcd-serial", SolverKind::Dcd, 1),
            ("passcode-wild", SolverKind::Passcode(MemoryModel::Wild), 4),
        ] {
            let cfg = RunConfig {
                dataset: "news20".into(),
                scale: 0.5,
                solver,
                loss,
                threads,
                epochs: 15,
                eval_every: 5,
                ..Default::default()
            };
            let out = driver::run(&cfg)?;
            println!(
                "  {label:<15} P = {:>12.5}  gap = {:>9.3e}  acc = {:.4}  ({:.3}s)",
                out.primal_final,
                out.gap_final,
                out.acc_what,
                out.result.train_secs()
            );
        }
        println!();
    }
    println!("logistic_regression OK");
    Ok(())
}
