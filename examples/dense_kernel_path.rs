//! Dense-path demo: the Pallas `dcd_block_epoch` kernel as the local
//! solver, driven from Rust through PJRT — a CoCoA-style dense training
//! loop where the inner compute is the AOT-compiled Layer-1 kernel.
//!
//! Workload: the covtype analog (d = 54, fully dense — the regime the
//! paper calls out as hardest for parallel DCD).  Rust partitions rows
//! into blocks, pads each to the exported (128 × 512) shape, runs the
//! kernel per block, and averages the deltas (β_K = 1, Jaggi et al.).
//!
//! ```text
//! make artifacts && cargo run --release --example dense_kernel_path
//! ```

use anyhow::Context;
use passcode::data::registry;
use passcode::eval;
use passcode::loss::Hinge;
use passcode::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load_default()
        .context("AOT artifacts missing — run `make artifacts`")?;
    let db = engine.manifest.dcd_row_block; // 128
    let fb = engine.manifest.feat_block; // 512
    let (train, test, c) = registry::load("covtype", 0.05)?;
    let (n, d) = (train.n(), train.d());
    assert!(d <= fb, "dense path requires d ≤ {fb}");
    println!(
        "covtype analog: n = {n}, d = {d}, C = {c}; kernel block {db}×{fb}"
    );

    // Pre-densify every row block once (padded to the export shape).
    // Block b owns rows [b·db, min((b+1)·db, n)); padding rows keep
    // qii = 0 so the kernel skips them.
    let n_blocks = n.div_ceil(db);
    let mut blocks: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_blocks);
    for b in 0..n_blocks {
        let lo = b * db;
        let hi = (lo + db).min(n);
        let mut x = vec![0f32; db * fb];
        let mut qii = vec![0f32; db];
        for (r, i) in (lo..hi).enumerate() {
            let (idx, vals) = train.x.row(i);
            for (j, v) in idx.iter().zip(vals) {
                x[r * fb + *j as usize] = *v as f32;
            }
            qii[r] = train.x.row_sqnorm(i) as f32;
        }
        blocks.push((x, qii));
    }

    let loss = Hinge::new(c);
    let mut alpha = vec![0.0f64; n_blocks * db];
    let mut w = vec![0.0f64; d];
    let k = n_blocks as f64; // CoCoA's K

    println!("\n  round      P(w)          gap          test acc");
    for round in 1..=20 {
        let mut dw_sum = vec![0.0f64; d];
        for (b, (x, qii)) in blocks.iter().enumerate() {
            let mut wblk = vec![0f32; fb];
            for j in 0..d {
                wblk[j] = w[j] as f32;
            }
            let a0: Vec<f32> = alpha[b * db..(b + 1) * db]
                .iter()
                .map(|&v| v as f32)
                .collect();
            let out = engine.execute(
                "dcd_block_epoch",
                &[
                    Engine::literal_f32(x, &[db as i64, fb as i64])?,
                    Engine::literal_f32(qii, &[db as i64, 1])?,
                    Engine::literal_f32(&[c as f32], &[1, 1])?,
                    Engine::literal_f32(&a0, &[db as i64, 1])?,
                    Engine::literal_f32(&wblk, &[fb as i64, 1])?,
                ],
            )?;
            let a_new = out[0].to_vec::<f32>()?;
            let w_new = out[1].to_vec::<f32>()?;
            // β_K = 1 averaging: global += Δ_local / K.
            for j in 0..d {
                dw_sum[j] += (w_new[j] as f64 - w[j]) / k;
            }
            for (r, dst) in
                alpha[b * db..(b + 1) * db].iter_mut().enumerate()
            {
                *dst += (a_new[r] as f64 - *dst) / k;
            }
        }
        for j in 0..d {
            w[j] += dw_sum[j];
        }

        let p = eval::primal_objective(&train, &loss, &w);
        let alpha_rows: Vec<f64> = (0..n).map(|i| alpha[i]).collect();
        let gap = eval::duality_gap(&train, &loss, &alpha_rows);
        let acc = eval::accuracy(&test, &w);
        if round % 2 == 0 || round == 1 {
            println!("  {round:>5}  {p:>12.5}  {gap:>11.4e}  {acc:>9.4}");
        }
    }
    println!("\ndense_kernel_path OK (inner solver = AOT Pallas kernel via PJRT)");
    Ok(())
}
