//! Multiclass one-vs-rest on top of PASSCoDe — LIBLINEAR's flagship
//! multiclass mode (Keerthi et al. 2008, cited by the paper) built from
//! K parallel binary dual problems, plus CV grid search for C.
//!
//! ```text
//! cargo run --release --example multiclass_ovr
//! ```

use passcode::coordinator::tuning;
use passcode::data::registry;
use passcode::loss::Hinge;
use passcode::solver::{
    multiclass::{synthetic_multiclass, OvrModel},
    lookup, MemoryModel, SolveOptions,
};

fn main() -> anyhow::Result<()> {
    // ---- multiclass OvR ------------------------------------------------
    let k = 5;
    let ds = synthetic_multiclass(3_000, 400, k, 25.0, 42);
    println!(
        "=== one-vs-rest: {} classes, n = {}, d = {} ===",
        k,
        ds.n(),
        ds.d()
    );
    let loss = Hinge::new(1.0);
    let opts = SolveOptions {
        threads: 4,
        epochs: 20,
        eval_every: 1,
        ..Default::default()
    };
    let (model, results) =
        OvrModel::train(&ds, &loss, MemoryModel::Wild, &opts);
    for (kk, r) in results.iter().enumerate() {
        println!(
            "  class {kk}: {} updates, train {:.3}s",
            r.updates,
            r.train_secs()
        );
    }
    let acc = model.accuracy(&ds);
    println!("train accuracy (argmax margin): {acc:.4}  (chance = {:.2})\n", 1.0 / k as f64);
    assert!(acc > 0.6, "multiclass accuracy too low: {acc}");

    // ---- C grid search ---------------------------------------------------
    println!("=== 3-fold CV grid search for C (rcv1 analog) ===");
    let (tr, _, _) = registry::load("rcv1", 0.05)?;
    let grid = [0.01, 0.1, 1.0, 10.0];
    let cv_opts = SolveOptions {
        threads: 2,
        epochs: 10,
        eval_every: 1,
        ..Default::default()
    };
    let trainer = lookup("passcode-wild")?;
    let (points, best) =
        tuning::grid_search_c(&tr, &grid, 3, &cv_opts, trainer.as_ref())?;
    println!("      C     mean val acc   folds");
    for p in &points {
        println!(
            "  {:>7}   {:.4}          {:?}",
            p.c,
            p.mean_acc,
            p.fold_accs
                .iter()
                .map(|a| (a * 1000.0).round() / 1000.0)
                .collect::<Vec<_>>()
        );
    }
    println!("best C = {best}");
    println!("\nmulticlass_ovr OK");
    Ok(())
}
