//! Quickstart: train a hinge-loss SVM with PASSCoDe-Wild on the rcv1
//! analog and print the convergence trace.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use passcode::coordinator::{driver, RunConfig, SolverKind};
use passcode::solver::MemoryModel;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        dataset: "rcv1".into(),
        scale: 0.1,
        solver: SolverKind::Passcode(MemoryModel::Wild),
        threads: 4,
        epochs: 15,
        eval_every: 1,
        ..Default::default()
    };
    println!("PASSCoDe quickstart — config {}", cfg.to_json());

    let out = driver::run(&cfg)?;
    println!("\n  epoch   time(s)       P(ŵ)          gap      test acc");
    for r in &out.metrics.rows {
        println!(
            "  {:>5}  {:>8.3}  {:>12.5}  {:>10.3e}  {:>9.4}",
            r.epoch, r.train_secs, r.primal, r.gap, r.test_acc
        );
    }
    println!(
        "\nfinal: P(ŵ) = {:.5}, duality gap = {:.3e}",
        out.primal_final, out.gap_final
    );
    println!(
        "accuracy: ŵ → {:.4}   w̄ → {:.4}   (predict with ŵ — Theorem 3)",
        out.acc_what, out.acc_wbar
    );
    println!(
        "{} updates in {:.3}s train (+{:.3}s init)",
        out.result.updates,
        out.result.train_secs(),
        out.result.init_secs()
    );
    Ok(())
}
