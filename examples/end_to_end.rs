//! End-to-end system driver (EXPERIMENTS.md §E2E): exercises every layer
//! of the stack on a real small workload.
//!
//! 1. generate the rcv1 analog (S3 data substrate),
//! 2. train hinge SVM with serial DCD, PASSCoDe-{Lock,Atomic,Wild},
//!    CoCoA (S5–S7), logging the full loss curve per epoch,
//! 3. evaluate the final model through BOTH the native sparse path and
//!    the AOT/PJRT path compiled from the Pallas kernels (S13, L1+L2) and
//!    cross-check them,
//! 4. replay the same workload on the multicore simulator (S10) for the
//!    10-core speedup estimate this host cannot measure,
//! 5. print a summary block that EXPERIMENTS.md records.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//! ```

use passcode::coordinator::{driver, RunConfig, SolverKind};
use passcode::data::registry;
use passcode::loss::Hinge;
use passcode::runtime::{Engine, Evaluator};
use passcode::simcore::{self, CostModel, Mechanism, SimConfig};
use passcode::solver::MemoryModel;

fn main() -> anyhow::Result<()> {
    let scale = 0.25;
    let epochs = 12;
    let threads = 4;
    println!("=== PASSCoDe end-to-end driver ===");
    println!("dataset rcv1-analog @ scale {scale}, {epochs} epochs, {threads} threads\n");

    // ---- 1+2: train all variants, log curves ------------------------
    let mut summaries = Vec::new();
    for (label, solver) in [
        ("dcd-serial", SolverKind::Dcd),
        ("passcode-lock", SolverKind::Passcode(MemoryModel::Lock)),
        ("passcode-atomic", SolverKind::Passcode(MemoryModel::Atomic)),
        ("passcode-wild", SolverKind::Passcode(MemoryModel::Wild)),
        ("cocoa", SolverKind::Cocoa),
    ] {
        let cfg = RunConfig {
            dataset: "rcv1".into(),
            scale,
            solver,
            threads: if label == "dcd-serial" { 1 } else { threads },
            epochs,
            eval_every: 1,
            ..Default::default()
        };
        let out = driver::run(&cfg)?;
        println!("--- {label} ---");
        println!("  epoch    P(ŵ)            gap       acc");
        for r in &out.metrics.rows {
            println!(
                "  {:>5}  {:>12.5}  {:>10.3e}  {:>7.4}",
                r.epoch, r.primal, r.gap, r.test_acc
            );
        }
        println!(
            "  final acc(ŵ) = {:.4}, acc(w̄) = {:.4}, train {:.3}s\n",
            out.acc_what,
            out.acc_wbar,
            out.result.train_secs()
        );
        summaries.push((label, out));
    }

    // ---- 3: AOT/PJRT cross-check on the wild model -------------------
    let wild = &summaries
        .iter()
        .find(|(l, _)| *l == "passcode-wild")
        .unwrap()
        .1;
    let (train, _, c) = registry::load("rcv1", scale)?;
    match Engine::load_default() {
        Ok(engine) => {
            let aot = Evaluator::new(&engine).eval(&train, &wild.result.w_hat)?;
            let native = wild.primal_final;
            let rel = (aot.primal(c) - native).abs() / native.abs().max(1.0);
            println!("AOT/PJRT cross-check (platform {}):", engine.platform());
            println!("  native P(ŵ) = {native:.6}");
            println!("  AOT    P(ŵ) = {:.6}  (rel err {rel:.2e})", aot.primal(c));
            assert!(rel < 2e-3, "AOT and native eval disagree");
        }
        Err(e) => {
            println!("AOT path skipped (run `make artifacts`): {e:#}");
        }
    }

    // ---- 4: simulated 10-core speedup -------------------------------
    println!("\nsimulated 10-core speedups (multicore DES, DESIGN.md §3):");
    let loss = Hinge::new(c);
    let cost = CostModel::default();
    let serial_ns = simcore::serial_reference_ns(&train, &loss, epochs, 7, &cost);
    for (mech, name) in [
        (Mechanism::Wild, "wild"),
        (Mechanism::Atomic, "atomic"),
        (Mechanism::Lock, "lock"),
    ] {
        let sim = simcore::simulate(
            &train,
            &loss,
            &SimConfig { cores: 10, epochs, seed: 7, cost, mechanism: mech, sockets: 1 },
        );
        println!(
            "  {name:<7} {:>6.2}x   (lost writes: {}, mean staleness {:.1})",
            serial_ns / sim.virtual_ns,
            sim.lost_writes,
            sim.mean_staleness
        );
    }

    // ---- 5: headline summary ----------------------------------------
    println!("\n=== summary ===");
    for (label, out) in &summaries {
        println!(
            "  {label:<16} P={:.5}  gap={:.2e}  acc(ŵ)={:.4}",
            out.primal_final, out.gap_final, out.acc_what
        );
    }
    let dcd = &summaries[0].1;
    let wild_acc = wild.acc_what;
    assert!(
        (wild_acc - dcd.acc_what).abs() < 0.02,
        "wild accuracy diverged from serial"
    );
    println!("\nend_to_end OK");
    Ok(())
}
