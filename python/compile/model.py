"""Layer-2 JAX evaluation graph for the PASSCoDe stack.

Composes the Layer-1 Pallas kernels into the fixed-shape entry points the
Rust runtime executes via PJRT:

  * ``margins_block``   — dense partial margins X_blk @ w_blk (the Rust
                          side accumulates across feature blocks),
  * ``eval_block``      — margins + masked hinge statistics in one program
                          (fused eval for row blocks whose full feature
                          width fits one export),
  * ``sumsq_block``     — blockwise ||w||^2 reduction for the regularizer,
  * ``dcd_block_epoch`` — dense block dual CD sweeps (CoCoA local solver /
                          dense end-to-end path).

Every function returns a tuple (the AOT bridge lowers with
``return_tuple=True``; the Rust side unwraps with ``to_tupleN``).
Shapes are fixed at export time by python/compile/aot.py and recorded in
artifacts/manifest.json; the Rust runtime pads blocks to match.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import dcd_block, hinge_stats, margins, sumsq

# Default export geometry.  Small enough that interpret-mode Pallas on a
# 1-core CPU stays fast; 128/256-multiples so a real TPU lowering would
# tile MXU-natively.
ROW_BLOCK = 256      # rows per eval block (B)
FEAT_BLOCK = 512     # features per block (D)
DCD_ROW_BLOCK = 128  # rows per dense DCD block
DCD_SWEEPS = 1       # CD sweeps per dcd_block_epoch call


def margins_block(x: jnp.ndarray, w: jnp.ndarray):
    """Partial margins for one (row-block × feature-block) tile.

    x: (B, Dblk) f32, w: (Dblk, 1) f32 -> ((B, 1) f32,).
    Rust accumulates the partial margins over feature blocks.
    """
    return (margins(x, w, bm=128, bd=256),)


def eval_block(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray):
    """Fused margins + masked hinge stats for one row block.

    x: (B, D) f32, w: (D, 1) f32, mask: (B, 1) f32 ->
    ((1,1) hinge_loss_sum, (1,1) correct_count, (B,1) margins).
    """
    m = margins(x, w, bm=128, bd=256)
    loss, correct = hinge_stats(m, mask, bm=128, squared=False)
    return (loss, correct, m)


def eval_block_sqhinge(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray):
    """Squared-hinge variant of :func:`eval_block`."""
    m = margins(x, w, bm=128, bd=256)
    loss, correct = hinge_stats(m, mask, bm=128, squared=True)
    return (loss, correct, m)


def loss_stats_block(margins_in: jnp.ndarray, mask: jnp.ndarray):
    """Masked hinge stats over precomputed margins.

    Used by the Rust runtime when the feature space spans multiple
    feature blocks: it accumulates `margins_block` outputs first, then
    reduces here.  margins_in, mask: (B, 1) -> ((1,1) loss, (1,1) correct).
    """
    return hinge_stats(margins_in, mask, bm=128, squared=False)


def loss_stats_block_sq(margins_in: jnp.ndarray, mask: jnp.ndarray):
    """Squared-hinge variant of :func:`loss_stats_block`."""
    return hinge_stats(margins_in, mask, bm=128, squared=True)


def sumsq_block(v: jnp.ndarray):
    """Blockwise sum of squares: (Dblk, 1) f32 -> ((1, 1) f32,)."""
    return (sumsq(v, bd=256),)


def dcd_block_epoch(x, qii, c, alpha, w):
    """Dense block dual CD epoch (DCD_SWEEPS cyclic sweeps).

    x: (B, D); qii: (B, 1) with 0 on padding rows; c: (1, 1); alpha: (B, 1);
    w: (D, 1).  Returns (alpha', w').
    """
    return dcd_block(x, qii, c, alpha, w, sweeps=DCD_SWEEPS)
