"""AOT bridge: lower the Layer-2 evaluation graph to HLO *text* artifacts.

Run once at build time (``make artifacts``); Python never appears on the
Rust request path.  Interchange is HLO text, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
the xla crate's XLA (xla_extension 0.5.1) rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def export_table():
    """(name, fn, input shapes, output shapes, notes) for every artifact."""
    b, d = model.ROW_BLOCK, model.FEAT_BLOCK
    db = model.DCD_ROW_BLOCK
    return [
        (
            "margins_block",
            model.margins_block,
            [(b, d), (d, 1)],
            [(b, 1)],
            "partial margins X_blk @ w_blk; accumulate over feature blocks",
        ),
        (
            "eval_block",
            model.eval_block,
            [(b, d), (d, 1), (b, 1)],
            [(1, 1), (1, 1), (b, 1)],
            "hinge loss sum, correct count, margins for one row block",
        ),
        (
            "eval_block_sqhinge",
            model.eval_block_sqhinge,
            [(b, d), (d, 1), (b, 1)],
            [(1, 1), (1, 1), (b, 1)],
            "squared-hinge variant of eval_block",
        ),
        (
            "loss_stats_block",
            model.loss_stats_block,
            [(b, 1), (b, 1)],
            [(1, 1), (1, 1)],
            "hinge stats over accumulated margins (multi-feature-block path)",
        ),
        (
            "loss_stats_block_sq",
            model.loss_stats_block_sq,
            [(b, 1), (b, 1)],
            [(1, 1), (1, 1)],
            "squared-hinge stats over accumulated margins",
        ),
        (
            "sumsq_block",
            model.sumsq_block,
            [(d, 1)],
            [(1, 1)],
            "blockwise ||v||^2 for the regularizer",
        ),
        (
            "dcd_block_epoch",
            model.dcd_block_epoch,
            [(db, d), (db, 1), (1, 1), (db, 1), (d, 1)],
            [(db, 1), (d, 1)],
            f"{model.DCD_SWEEPS} dense cyclic DCD sweep(s); "
            "qii==0 marks padding rows",
        ),
    ]


def build(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text",
        "jax_version": jax.__version__,
        "row_block": model.ROW_BLOCK,
        "feat_block": model.FEAT_BLOCK,
        "dcd_row_block": model.DCD_ROW_BLOCK,
        "dcd_sweeps": model.DCD_SWEEPS,
        "artifacts": {},
    }
    for name, fn, in_shapes, out_shapes, note in export_table():
        specs = [_spec(s) for s in in_shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [list(s) for s in in_shapes],
            "outputs": [list(s) for s in out_shapes],
            "dtype": "f32",
            "note": note,
        }
        if verbose:
            print(f"  {name}: {len(text)} chars -> {path}")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if verbose:
        print(f"  manifest -> {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
