"""Layer-1 Pallas kernel: dense block dual coordinate descent (hinge).

The compute analog of the paper's inner solver: ``sweeps`` sequential
passes of Algorithm 1 over a dense block of rows, with the block-local
primal vector ``w`` maintained in VMEM.  This is the local solver the
CoCoA baseline runs per block, and the dense-path workhorse of the
end-to-end example (covtype-analog, d small).

Coordinate descent is intrinsically sequential inside a block; on TPU that
maps to a ``fori_loop`` over a VMEM-resident tile (dot products hit the
VPU/MXU per row), not to a parallel grid.  The *parallelism across blocks*
is what the Rust coordinator owns.  interpret=True on this image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dcd_block_kernel(x_ref, qii_ref, c_ref, alpha_ref, w_ref,
                      alpha_out_ref, w_out_ref, *, sweeps: int):
    # Copy the state into the output refs; the sweeps mutate those in VMEM.
    alpha_out_ref[...] = alpha_ref[...]
    w_out_ref[...] = w_ref[...]
    b = x_ref.shape[0]
    c = c_ref[0, 0]

    def body(k, _):
        i = k % b
        xi = x_ref[i, :]                      # (D,)
        qi = qii_ref[i, 0]
        ai = alpha_out_ref[i, 0]
        w = w_out_ref[...]                    # (D, 1)
        g = jnp.dot(xi, w[:, 0]) - 1.0        # gradient of the subproblem
        # Guard padding rows (qii == 0): keep alpha, delta = 0.
        safe_q = jnp.where(qi > 0.0, qi, 1.0)
        a_new = jnp.clip(ai - g / safe_q, 0.0, c)
        delta = jnp.where(qi > 0.0, a_new - ai, 0.0)
        alpha_out_ref[i, 0] = ai + delta
        w_out_ref[...] = w + delta * xi[:, None]
        return 0

    jax.lax.fori_loop(0, sweeps * b, body, 0)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def dcd_block(x, qii, c, alpha, w, *, sweeps: int = 1):
    """Run ``sweeps`` cyclic DCD passes over a dense block.

    x: (B, D) f32; qii: (B, 1) row squared norms (0 marks padding rows);
    c: (1, 1) box constraint; alpha: (B, 1); w: (D, 1) block-local primal
    vector consistent with alpha.  Returns (alpha', w').
    """
    b, d = x.shape
    assert qii.shape == (b, 1) and alpha.shape == (b, 1)
    assert w.shape == (d, 1) and c.shape == (1, 1)
    kernel = functools.partial(_dcd_block_kernel, sweeps=sweeps)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((d, 1), jnp.float32),
        ),
        interpret=True,
    )(x, qii, c, alpha, w)
