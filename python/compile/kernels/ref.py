"""Pure-jnp / pure-python reference oracles for the Pallas kernels.

Every kernel in this package must agree with the corresponding function in
this module (pytest + hypothesis enforce it).  The references are written
with deliberately *different* mechanics than the kernels — plain `jnp`
matmuls and Python loops — so a shared bug is unlikely.

Conventions (match the paper): rows of ``x`` are already label-folded,
``x_i = y_i * xdot_i``, so a positive margin ``w.T x_i > 0`` is a correct
prediction and the hinge loss is ``C * max(0, 1 - w.T x_i)``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def margins_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Margins ``m = X @ w`` for a dense block.

    x: (B, D) float32, w: (D, 1) float32 -> (B, 1) float32.
    """
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)


def hinge_stats_ref(margins: jnp.ndarray, mask: jnp.ndarray):
    """Masked hinge-loss sum and correct-prediction count.

    margins: (B, 1); mask: (B, 1) in {0.0, 1.0} marking live rows
    (padding rows carry 0 and must not contribute).

    Returns (loss_sum, correct) each shaped (1, 1):
      loss_sum = sum_i mask_i * max(0, 1 - m_i)
      correct  = sum_i mask_i * [m_i > 0]
    """
    m = jnp.asarray(margins, jnp.float32)
    msk = jnp.asarray(mask, jnp.float32)
    loss = jnp.sum(msk * jnp.maximum(0.0, 1.0 - m)).reshape(1, 1)
    correct = jnp.sum(msk * (m > 0.0).astype(jnp.float32)).reshape(1, 1)
    return loss, correct


def squared_hinge_stats_ref(margins: jnp.ndarray, mask: jnp.ndarray):
    """Masked squared-hinge sum and correct count, same shapes as hinge."""
    m = jnp.asarray(margins, jnp.float32)
    msk = jnp.asarray(mask, jnp.float32)
    h = jnp.maximum(0.0, 1.0 - m)
    loss = jnp.sum(msk * h * h).reshape(1, 1)
    correct = jnp.sum(msk * (m > 0.0).astype(jnp.float32)).reshape(1, 1)
    return loss, correct


def sumsq_ref(v: jnp.ndarray) -> jnp.ndarray:
    """Sum of squares of a (D, 1) block -> (1, 1)."""
    v = jnp.asarray(v, jnp.float32)
    return jnp.sum(v * v).reshape(1, 1)


def dcd_block_ref(
    x: np.ndarray,
    qii: np.ndarray,
    alpha0: np.ndarray,
    w0: np.ndarray,
    c: float,
    sweeps: int,
):
    """Reference dense block dual coordinate descent (hinge loss).

    Sequentially sweeps the block's coordinates ``sweeps`` times, exactly
    Algorithm 1 of the paper restricted to the block, with the local ``w``
    kept in sync:

        G     = w.T x_i - 1
        a_new = clip(alpha_i - G / qii_i, 0, C)
        w    += (a_new - alpha_i) x_i

    Rows with qii_i == 0 (padding) are skipped.  Pure numpy + Python loop
    (the kernel uses a lax.fori_loop over VMEM refs).

    x: (B, D); qii: (B, 1) row squared norms; alpha0: (B, 1); w0: (D, 1).
    Returns (alpha, w) after the sweeps.
    """
    x = np.asarray(x, np.float64)
    alpha = np.asarray(alpha0, np.float64).copy().reshape(-1)
    w = np.asarray(w0, np.float64).copy().reshape(-1)
    q = np.asarray(qii, np.float64).reshape(-1)
    b = x.shape[0]
    for _ in range(int(sweeps)):
        for i in range(b):
            if q[i] <= 0.0:
                continue  # padding row
            g = float(x[i] @ w) - 1.0
            a_new = min(max(alpha[i] - g / q[i], 0.0), float(c))
            d = a_new - alpha[i]
            if d != 0.0:
                alpha[i] = a_new
                w += d * x[i]
    return (
        alpha.reshape(-1, 1).astype(np.float32),
        w.reshape(-1, 1).astype(np.float32),
    )


def primal_objective_ref(x: np.ndarray, w: np.ndarray, c: float) -> float:
    """Full primal objective P(w) = 0.5||w||^2 + C sum max(0, 1 - Xw)."""
    w = np.asarray(w, np.float64).reshape(-1)
    m = np.asarray(x, np.float64) @ w
    return 0.5 * float(w @ w) + float(c) * float(
        np.maximum(0.0, 1.0 - m).sum()
    )


def dual_objective_ref(x: np.ndarray, alpha: np.ndarray, c: float) -> float:
    """Hinge dual D(alpha) = 0.5||sum_i alpha_i x_i||^2 - sum_i alpha_i.

    (Valid on the box 0 <= alpha_i <= C; the conjugate of the hinge loss.)
    """
    a = np.asarray(alpha, np.float64).reshape(-1)
    assert np.all(a >= -1e-12) and np.all(a <= c + 1e-12)
    wbar = np.asarray(x, np.float64).T @ a
    return 0.5 * float(wbar @ wbar) - float(a.sum())
