"""Layer-1 Pallas kernel: tiled dense margins ``m = X @ w``.

This is the bulk-evaluation hot-spot of the stack: the Rust coordinator
streams dense feature blocks of the (padded) data matrix through the AOT
executable to score/evaluate a model without touching Python.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows × feature
blocks; each (bm × bd) tile of X and (bd × 1) slice of w are staged into
VMEM by the BlockSpec pipeline, the partial product targets the MXU, and
the (bm × 1) output tile is accumulated in place across the feature-block
grid dimension (classic "reduce over grid axis 1" pattern).  On this image
the kernel runs with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the structure is what a real TPU lowering would pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _margins_kernel(x_ref, w_ref, o_ref):
    """One grid step: o[bm,1] (+)= x[bm,bd] @ w[bd,1]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bd"))
def margins(x: jnp.ndarray, w: jnp.ndarray, *, bm: int = 128, bd: int = 256):
    """Tiled margins for a dense block.

    x: (B, D) f32 with B % bm == 0 and D % bd == 0 (the AOT exporter and
    the Rust runtime always pad to the exported shape); w: (D, 1) f32.
    Returns (B, 1) f32.
    """
    b, d = x.shape
    assert b % bm == 0 and d % bd == 0, (b, d, bm, bd)
    assert w.shape == (d, 1), w.shape
    grid = (b // bm, d // bd)
    return pl.pallas_call(
        _margins_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=True,
    )(x, w)
