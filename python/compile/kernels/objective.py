"""Layer-1 Pallas kernels: masked loss statistics and sum-of-squares.

Reduction kernels used by the L2 evaluation graph.  Each accumulates a
scalar across the grid into a (1, 1) output tile — the standard Pallas
"scalar accumulator lives in the output ref" reduction idiom.

All kernels run with ``interpret=True`` on this image (see margins.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hinge_stats_kernel(m_ref, mask_ref, loss_ref, correct_ref, *, squared):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)
        correct_ref[...] = jnp.zeros_like(correct_ref)

    m = m_ref[...]
    msk = mask_ref[...]
    h = jnp.maximum(0.0, 1.0 - m)
    if squared:
        h = h * h
    loss_ref[...] += jnp.sum(msk * h).reshape(1, 1)
    correct_ref[...] += jnp.sum(
        msk * (m > 0.0).astype(jnp.float32)
    ).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("bm", "squared"))
def hinge_stats(
    margins: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    bm: int = 128,
    squared: bool = False,
):
    """Masked (squared-)hinge loss sum and correct count.

    margins, mask: (B, 1) f32 with B % bm == 0; mask is 1.0 on live rows,
    0.0 on padding.  Returns ((1,1) loss_sum, (1,1) correct_count).
    """
    b = margins.shape[0]
    assert margins.shape == (b, 1) and mask.shape == (b, 1)
    assert b % bm == 0, (b, bm)
    kernel = functools.partial(_hinge_stats_kernel, squared=squared)
    return pl.pallas_call(
        kernel,
        grid=(b // bm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=True,
    )(margins, mask)


def _sumsq_kernel(v_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    v = v_ref[...]
    o_ref[...] += jnp.sum(v * v).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("bd",))
def sumsq(v: jnp.ndarray, *, bd: int = 256):
    """Sum of squares of a (D, 1) f32 vector, D % bd == 0 -> (1, 1)."""
    d = v.shape[0]
    assert v.shape == (d, 1) and d % bd == 0, (v.shape, bd)
    return pl.pallas_call(
        _sumsq_kernel,
        grid=(d // bd,),
        in_specs=[pl.BlockSpec((bd, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(v)
