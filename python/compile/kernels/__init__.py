"""Pallas kernels (Layer 1) and their pure-jnp oracles.

Exports: margins (tiled X@w), hinge_stats / sumsq reductions, dcd_block
(sequential dense block dual coordinate descent), and the ``ref`` module
with the correctness oracles.
"""

from . import ref  # noqa: F401
from .dcd_block import dcd_block  # noqa: F401
from .margins import margins  # noqa: F401
from .objective import hinge_stats, sumsq  # noqa: F401
