"""Layer-2 model graph: shapes and numerics of the exported entry points."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rand(rng, shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


def test_eval_block_shapes():
    b, d = model.ROW_BLOCK, model.FEAT_BLOCK
    rng = np.random.default_rng(0)
    x, w = _rand(rng, (b, d)), _rand(rng, (d, 1))
    mask = np.ones((b, 1), np.float32)
    loss, correct, m = model.eval_block(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(mask)
    )
    assert loss.shape == (1, 1)
    assert correct.shape == (1, 1)
    assert m.shape == (b, 1)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), live=st.integers(0, 256))
def test_eval_block_matches_numpy(seed, live):
    b, d = model.ROW_BLOCK, model.FEAT_BLOCK
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (b, d), 0.2), _rand(rng, (d, 1), 0.2)
    mask = np.zeros((b, 1), np.float32)
    mask[:live] = 1.0
    loss, correct, m = model.eval_block(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(mask)
    )
    m_np = (x.astype(np.float64) @ w.astype(np.float64)).reshape(-1)
    want_loss = np.maximum(0.0, 1.0 - m_np[:live]).sum()
    want_correct = float((m_np[:live] > 0).sum())
    np.testing.assert_allclose(
        np.asarray(loss).item(), want_loss, rtol=2e-4, atol=2e-3
    )
    # correct-count can flip on |margin| ~ f32 eps; allow 1-off
    assert abs(np.asarray(correct).item() - want_correct) <= 1.0


def test_eval_block_sqhinge_vs_ref():
    b, d = model.ROW_BLOCK, model.FEAT_BLOCK
    rng = np.random.default_rng(42)
    x, w = _rand(rng, (b, d), 0.2), _rand(rng, (d, 1), 0.2)
    mask = np.ones((b, 1), np.float32)
    loss, correct, m = model.eval_block_sqhinge(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(mask)
    )
    want_l, want_c = ref.squared_hinge_stats_ref(np.asarray(m), mask)
    np.testing.assert_allclose(
        np.asarray(loss), np.asarray(want_l), rtol=2e-4, atol=2e-3
    )
    np.testing.assert_allclose(np.asarray(correct), np.asarray(want_c))


def test_sumsq_block_matches():
    d = model.FEAT_BLOCK
    rng = np.random.default_rng(1)
    v = _rand(rng, (d, 1))
    (got,) = model.sumsq_block(jnp.asarray(v))
    np.testing.assert_allclose(
        np.asarray(got).item(), float((v.astype(np.float64) ** 2).sum()),
        rtol=2e-5,
    )


def test_margins_block_accumulation_across_feature_blocks():
    """Rust accumulates partial margins over feature blocks; verify the
    contract: sum of per-block margins == full margins."""
    b, d = model.ROW_BLOCK, model.FEAT_BLOCK
    rng = np.random.default_rng(2)
    x_full = _rand(rng, (b, 2 * d), 0.3)
    w_full = _rand(rng, (2 * d, 1), 0.3)
    (m0,) = model.margins_block(
        jnp.asarray(x_full[:, :d]), jnp.asarray(w_full[:d])
    )
    (m1,) = model.margins_block(
        jnp.asarray(x_full[:, d:]), jnp.asarray(w_full[d:])
    )
    total = np.asarray(m0) + np.asarray(m1)
    want = x_full.astype(np.float64) @ w_full.astype(np.float64)
    np.testing.assert_allclose(total, want, rtol=2e-4, atol=2e-3)


def test_dcd_block_epoch_converges_on_separable_data():
    """A few epochs of the dense DCD block must reach low primal-dual gap
    on a small separable problem (the e2e dense path contract)."""
    b, d, c = model.DCD_ROW_BLOCK, model.FEAT_BLOCK, 1.0
    rng = np.random.default_rng(9)
    wstar = _rand(rng, (d, 1), 1.0)
    x = _rand(rng, (b, d), 1.0) / np.sqrt(d)
    y = np.sign(x @ wstar).astype(np.float32)
    x = x * y  # label-folded rows
    qii = (x * x).sum(axis=1, keepdims=True).astype(np.float32)
    alpha = np.zeros((b, 1), np.float32)
    w = np.zeros((d, 1), np.float32)
    c_arr = np.full((1, 1), c, np.float32)
    for _ in range(30):
        alpha, w = model.dcd_block_epoch(
            jnp.asarray(x), jnp.asarray(qii), jnp.asarray(c_arr),
            jnp.asarray(alpha), jnp.asarray(w),
        )
        alpha, w = np.asarray(alpha), np.asarray(w)
    p = ref.primal_objective_ref(x, w, c)
    dneg = -ref.dual_objective_ref(x, np.clip(alpha, 0, c), c)
    gap = p - dneg
    assert gap < 0.05 * max(1.0, abs(p))
