"""AOT pipeline: lowering produces valid HLO text + consistent manifest."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, verbose=False)
    return out, manifest


def test_every_artifact_written(built):
    out, manifest = built
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(out, entry["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text, f"{name}: not HLO text"
        assert "HloModule" in text, f"{name}: missing module header"


def test_manifest_roundtrips_as_json(built):
    out, manifest = built
    on_disk = json.load(open(os.path.join(out, "manifest.json")))
    assert on_disk == json.loads(json.dumps(manifest))
    assert on_disk["format"] == "hlo-text"
    assert on_disk["row_block"] == model.ROW_BLOCK
    assert on_disk["feat_block"] == model.FEAT_BLOCK


def test_artifact_parameter_counts(built):
    """Parameter declarations in the HLO text match the manifest inputs."""
    out, manifest = built
    for name, entry in manifest["artifacts"].items():
        text = open(os.path.join(out, entry["file"])).read()
        entry_block = text[text.index("ENTRY"):]
        n_params = entry_block.count("parameter(")
        assert n_params == len(entry["inputs"]), (
            f"{name}: {n_params} params vs {len(entry['inputs'])} inputs"
        )


def test_no_mosaic_custom_calls(built):
    """interpret=True must lower Pallas to plain HLO (no Mosaic custom
    calls — the CPU PJRT client cannot execute those)."""
    out, manifest = built
    for name, entry in manifest["artifacts"].items():
        text = open(os.path.join(out, entry["file"])).read()
        assert "tpu_custom_call" not in text, name
        assert "mosaic" not in text.lower(), name
