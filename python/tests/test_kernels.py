"""Kernel-vs-oracle correctness: the CORE Layer-1 signal.

Hypothesis sweeps shapes/seeds; every Pallas kernel must match its pure
reference in kernels/ref.py to float32 tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dcd_block, hinge_stats, margins, ref, sumsq

SET = dict(max_examples=20, deadline=None)


def rand(rng, shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


# ----------------------------------------------------------------- margins
@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    rb=st.integers(1, 3),
    fb=st.integers(1, 3),
)
def test_margins_matches_ref(seed, rb, fb):
    rng = np.random.default_rng(seed)
    b, d = 128 * rb, 256 * fb
    x, w = rand(rng, (b, d)), rand(rng, (d, 1))
    got = margins(jnp.asarray(x), jnp.asarray(w), bm=128, bd=256)
    want = ref.margins_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-4
    )


def test_margins_zero_w_gives_zero():
    x = np.ones((128, 256), np.float32)
    w = np.zeros((256, 1), np.float32)
    got = margins(jnp.asarray(x), jnp.asarray(w), bm=128, bd=256)
    assert np.all(np.asarray(got) == 0.0)


def test_margins_rejects_misaligned_shapes():
    x = np.zeros((100, 256), np.float32)
    w = np.zeros((256, 1), np.float32)
    with pytest.raises(AssertionError):
        margins(jnp.asarray(x), jnp.asarray(w), bm=128, bd=256)


def test_margins_identity_columns():
    # x = eye-ish: row i selects feature i => margins = w[:B]
    b, d = 128, 256
    x = np.zeros((b, d), np.float32)
    x[np.arange(b), np.arange(b)] = 1.0
    rng = np.random.default_rng(7)
    w = rand(rng, (d, 1))
    got = np.asarray(margins(jnp.asarray(x), jnp.asarray(w), bm=128, bd=256))
    np.testing.assert_allclose(got, w[:b], rtol=1e-6)


# ------------------------------------------------------------- hinge stats
@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    rb=st.integers(1, 4),
    squared=st.booleans(),
    mask_p=st.floats(0.0, 1.0),
)
def test_hinge_stats_matches_ref(seed, rb, squared, mask_p):
    rng = np.random.default_rng(seed)
    b = 128 * rb
    m = rand(rng, (b, 1), scale=2.0)
    mask = (rng.random((b, 1)) < mask_p).astype(np.float32)
    got_l, got_c = hinge_stats(
        jnp.asarray(m), jnp.asarray(mask), bm=128, squared=squared
    )
    want = (
        ref.squared_hinge_stats_ref(m, mask)
        if squared
        else ref.hinge_stats_ref(m, mask)
    )
    np.testing.assert_allclose(
        np.asarray(got_l), np.asarray(want[0]), rtol=3e-5, atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want[1]))


def test_hinge_stats_all_masked_out_is_zero():
    m = np.full((128, 1), -5.0, np.float32)
    mask = np.zeros((128, 1), np.float32)
    l, c = hinge_stats(jnp.asarray(m), jnp.asarray(mask), bm=128)
    assert np.asarray(l).item() == 0.0 and np.asarray(c).item() == 0.0


def test_hinge_stats_margin_exactly_one_has_zero_loss():
    m = np.ones((128, 1), np.float32)
    mask = np.ones((128, 1), np.float32)
    l, c = hinge_stats(jnp.asarray(m), jnp.asarray(mask), bm=128)
    assert np.asarray(l).item() == 0.0
    assert np.asarray(c).item() == 128.0


# ------------------------------------------------------------------ sumsq
@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), fb=st.integers(1, 4))
def test_sumsq_matches_ref(seed, fb):
    rng = np.random.default_rng(seed)
    v = rand(rng, (256 * fb, 1))
    got = sumsq(jnp.asarray(v), bd=256)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.sumsq_ref(v)), rtol=3e-5, atol=1e-4
    )


# -------------------------------------------------------------- dcd block
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    sweeps=st.integers(1, 3),
    c=st.floats(0.05, 4.0),
    pad=st.integers(0, 4),
)
def test_dcd_block_matches_ref(seed, sweeps, c, pad):
    rng = np.random.default_rng(seed)
    b, d = 16, 32
    x = rand(rng, (b, d), scale=0.4)
    if pad:
        x[-pad:] = 0.0
    qii = (x * x).sum(axis=1, keepdims=True).astype(np.float32)
    alpha0 = np.clip(rand(rng, (b, 1), 0.2), 0, c).astype(np.float32)
    if pad:
        alpha0[-pad:] = 0.0
    w0 = (x.T @ alpha0).astype(np.float32)
    c_arr = np.full((1, 1), c, np.float32)
    got_a, got_w = dcd_block(
        jnp.asarray(x), jnp.asarray(qii), jnp.asarray(c_arr),
        jnp.asarray(alpha0), jnp.asarray(w0), sweeps=sweeps,
    )
    want_a, want_w = ref.dcd_block_ref(x, qii, alpha0, w0, c, sweeps)
    np.testing.assert_allclose(np.asarray(got_a), want_a, rtol=1e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_w), want_w, rtol=1e-4, atol=2e-5)


def test_dcd_block_decreases_dual_objective():
    rng = np.random.default_rng(3)
    b, d, c = 32, 64, 1.0
    x = rand(rng, (b, d), scale=0.3)
    qii = (x * x).sum(axis=1, keepdims=True).astype(np.float32)
    alpha0 = np.zeros((b, 1), np.float32)
    w0 = np.zeros((d, 1), np.float32)
    c_arr = np.full((1, 1), c, np.float32)
    d0 = ref.dual_objective_ref(x, alpha0, c)
    a, w = alpha0, w0
    prev = d0
    for _ in range(4):
        a, w = dcd_block(
            jnp.asarray(x), jnp.asarray(qii), jnp.asarray(c_arr),
            jnp.asarray(a), jnp.asarray(w), sweeps=1,
        )
        a, w = np.asarray(a), np.asarray(w)
        cur = ref.dual_objective_ref(x, np.clip(a, 0, c), c)
        assert cur <= prev + 1e-5
        prev = cur
    assert prev < d0  # made real progress


def test_dcd_block_keeps_alpha_in_box():
    rng = np.random.default_rng(11)
    b, d, c = 16, 32, 0.25
    x = rand(rng, (b, d))
    qii = (x * x).sum(axis=1, keepdims=True).astype(np.float32)
    a, w = dcd_block(
        jnp.asarray(x), jnp.asarray(qii),
        jnp.asarray(np.full((1, 1), c, np.float32)),
        jnp.asarray(np.zeros((b, 1), np.float32)),
        jnp.asarray(np.zeros((d, 1), np.float32)),
        sweeps=2,
    )
    a = np.asarray(a)
    assert np.all(a >= 0.0) and np.all(a <= c + 1e-6)


def test_dcd_block_padding_rows_untouched():
    rng = np.random.default_rng(5)
    b, d, c = 16, 32, 1.0
    x = rand(rng, (b, d), scale=0.4)
    x[10:] = 0.0
    qii = (x * x).sum(axis=1, keepdims=True).astype(np.float32)
    a0 = np.zeros((b, 1), np.float32)
    a, _ = dcd_block(
        jnp.asarray(x), jnp.asarray(qii),
        jnp.asarray(np.full((1, 1), c, np.float32)),
        jnp.asarray(a0), jnp.asarray(np.zeros((d, 1), np.float32)),
        sweeps=2,
    )
    assert np.all(np.asarray(a)[10:] == 0.0)
